//! OmniQuant-style clipped quantization (paper Appendix A.3 / Tab. 8).
//!
//! OmniQuant's Learnable Weight Clipping trains clip factors by
//! gradient descent; at this scale a dense grid search over the clip
//! factor per (group, column) finds the same optimum directly (the
//! objective is 1-D and piecewise smooth). The searched params can
//! back any quantizer; `quantize_lwc` runs plain RTN with them, and
//! `pmq::quantize` can pass them into the GPTQ loop.

use crate::tensor::Mat;

use super::linear::{dequantize_value, effective_group, quantize_value, GroupParams};
use super::pack::{pack_levels, PackedTensor};

/// Clip grid: fractions of the full min/max range to keep.
pub const CLIP_GRID: [f32; 8] = [1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.65];

/// Search the best clip factor per column for rows [r0, r0+GROUP).
pub fn clipped_group_params(w: &Mat, r0: usize, group: usize,
                            bits: usize) -> GroupParams {
    let qmax = ((1usize << bits) - 1) as f32;
    let n = w.cols;
    let r1 = (r0 + group).min(w.rows);
    let mut scales = vec![0.0f32; n];
    let mut zeros = vec![0.0f32; n];
    for c in 0..n {
        let col: Vec<f32> = (r0..r1).map(|r| w.at(r, c)).collect();
        let (lo0, hi0) = col
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
                (l.min(v), h.max(v))
            });
        let mut best = (f32::INFINITY, 1e-8f32, 0.0f32);
        for &clip in &CLIP_GRID {
            let (lo, hi) = (lo0 * clip, hi0 * clip);
            let scale = ((hi - lo) / qmax).max(1e-8);
            let zero = -lo / scale;
            let mut mse = 0.0;
            for &v in &col {
                let q = quantize_value(v, scale, zero, bits);
                let d = v - dequantize_value(q, scale, zero);
                mse += d * d;
            }
            if mse < best.0 {
                best = (mse, scale, zero);
            }
        }
        scales[c] = best.1;
        zeros[c] = best.2;
    }
    GroupParams { scales, zeros }
}

/// Full-matrix clipped RTN quantization.
pub fn quantize_lwc(w: &Mat, bits: usize) -> PackedTensor {
    let (k, n) = (w.rows, w.cols);
    let group = effective_group(k);
    let groups = k / group;
    let mut q = vec![0u32; k * n];
    let mut scales = vec![0.0f32; groups * n];
    let mut zeros = vec![0.0f32; groups * n];
    for g in 0..groups {
        let p = clipped_group_params(w, g * group, group, bits);
        scales[g * n..(g + 1) * n].copy_from_slice(&p.scales);
        zeros[g * n..(g + 1) * n].copy_from_slice(&p.zeros);
        for r in g * group..(g + 1) * group {
            for c in 0..n {
                q[r * n + c] = quantize_value(w.at(r, c), p.scales[c], p.zeros[c], bits);
            }
        }
    }
    PackedTensor {
        bits,
        k,
        n,
        group,
        qweight: pack_levels(&q, k, n, bits).into(),
        scales: scales.into(),
        zeros: zeros.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::linear::quantize_groupwise;
    use crate::util::rng::Rng;

    /// Heavy-tailed weights are where clipping wins: one outlier blows
    /// up the min/max scale and RTN wastes levels on it.
    fn heavy_tailed(rng: &mut Rng, k: usize, n: usize) -> Mat {
        let mut w = Mat::randn(rng, k, n, 0.5);
        for c in 0..n {
            let r = rng.below(k);
            let v = w.at(r, c) + 8.0 * if rng.f32() > 0.5 { 1.0 } else { -1.0 };
            w.set(r, c, v);
        }
        w
    }

    #[test]
    fn lwc_no_worse_than_rtn_mse() {
        let mut rng = Rng::new(0);
        let w = heavy_tailed(&mut rng, 128, 16);
        for &bits in &[2usize, 3] {
            let lwc = quantize_lwc(&w, bits).dequantize();
            let rtn = quantize_groupwise(&w, bits).dequantize();
            let e_lwc = w.sub(&lwc).fro_norm();
            let e_rtn = w.sub(&rtn).fro_norm();
            assert!(e_lwc <= e_rtn + 1e-5, "bits={bits} {e_lwc} vs {e_rtn}");
        }
    }

    #[test]
    fn lwc_strictly_better_on_outliers_2bit() {
        let mut rng = Rng::new(1);
        let w = heavy_tailed(&mut rng, 256, 8);
        let e_lwc = w.sub(&quantize_lwc(&w, 2).dequantize()).fro_norm();
        let e_rtn = w.sub(&quantize_groupwise(&w, 2).dequantize()).fro_norm();
        assert!(e_lwc < e_rtn, "{e_lwc} !< {e_rtn}");
    }

    #[test]
    fn gaussian_weights_prefer_mild_clipping() {
        // with pure gaussians the chosen clip should rarely be extreme
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 64, 8, 1.0);
        let p = clipped_group_params(&w, 0, 64, 3);
        for c in 0..8 {
            assert!(p.scales[c] > 0.0 && p.zeros[c].is_finite());
        }
    }
}
