//! Multiple-choice benchmark suite: 8 tasks scored LM-Eval-style
//! (length-normalized choice log-likelihood, argmax), zero-shot (Tab.
//! 2/5/8), few-shot (Tab. 3/6), NIAH grid (Fig. 9), and the CoT chain
//! (GSM8K-analogue, Tab. 9).

use crate::config::{TASK_ANALOGUE, TASK_NAMES};
use crate::data::niah::niah_sample;
use crate::data::tasks::{eval_sample, fewshot_sample, EvalSample};
use crate::data::TextChannel;
use crate::moe::model::{ForwardOpts, MoeModel, NullSink, OdpPolicy, RunStats};
use crate::tensor::log_softmax_into;
use crate::util::rng::Rng;
use crate::util::stats::argmax;

/// Score one multiple-choice sample; returns (correct, stats).
pub fn score_sample(model: &MoeModel, sample: &EvalSample,
                    odp: Option<&OdpPolicy>) -> (bool, RunStats) {
    let single_token = sample.choices.iter().all(|c| c.len() == 1);
    let mut stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
    let pick = if single_token {
        // one forward: compare choice-token logprobs at the last position
        let opts = ForwardOpts { odp, ..Default::default() };
        let out = model.forward(&sample.prompt, &opts, &mut NullSink);
        stats.merge(&out.stats);
        let mut lp = Vec::new();
        log_softmax_into(out.logits.row(sample.prompt.len() - 1), &mut lp);
        let scores: Vec<f32> = sample
            .choices
            .iter()
            .map(|c| lp[c[0] as usize])
            .collect();
        argmax(&scores)
    } else {
        // teacher-force each continuation, length-normalized
        let mut scores = Vec::with_capacity(sample.choices.len());
        for choice in &sample.choices {
            let mut toks = sample.prompt.clone();
            toks.extend(choice);
            let opts = ForwardOpts { odp, ..Default::default() };
            let out = model.forward(&toks, &opts, &mut NullSink);
            stats.merge(&out.stats);
            let lp = MoeModel::continuation_logprob(
                &out.logits, &toks, sample.prompt.len());
            scores.push(lp / choice.len() as f32);
        }
        argmax(&scores)
    };
    (pick == sample.gold, stats)
}

/// Accuracy of one task over `n_samples` (zero-shot if shots == 0).
pub fn eval_task(model: &MoeModel, task: usize, n_samples: usize,
                 shots: usize, seed: u64, odp: Option<&OdpPolicy>) -> (f64, RunStats) {
    let mut rng = Rng::new(seed ^ (task as u64) << 8);
    let mut correct = 0usize;
    let mut stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
    for _ in 0..n_samples {
        let sample = if shots == 0 {
            eval_sample(&mut rng, task)
        } else {
            fewshot_sample(&mut rng, task, shots)
        };
        let (ok, s) = score_sample(model, &sample, odp);
        correct += ok as usize;
        stats.merge(&s);
    }
    (correct as f64 / n_samples as f64, stats)
}

#[derive(Debug, Clone)]
pub struct SuiteReport {
    /// (task name, paper-benchmark analogue, accuracy)
    pub rows: Vec<(String, String, f64)>,
    pub average: f64,
    pub stats: RunStats,
}

/// Full 8-task suite (the paper's Tab.-2 row for one model).
pub fn eval_suite(model: &MoeModel, n_samples: usize, shots: usize,
                  seed: u64, odp: Option<&OdpPolicy>) -> SuiteReport {
    let mut rows = Vec::with_capacity(8);
    let mut stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
    let mut total = 0.0;
    for task in 0..8 {
        let (acc, s) = eval_task(model, task, n_samples, shots, seed, odp);
        stats.merge(&s);
        total += acc;
        rows.push((
            TASK_NAMES[task].to_string(),
            TASK_ANALOGUE[task].to_string(),
            acc,
        ));
    }
    SuiteReport { rows, average: total / 8.0, stats }
}

/// NIAH retrieval accuracy over a (context length × depth) grid (Fig. 9).
pub fn eval_niah_grid(model: &MoeModel, lengths: &[usize], depths: &[f64],
                      n_samples: usize, seed: u64,
                      odp: Option<&OdpPolicy>) -> Vec<Vec<f64>> {
    let text = TextChannel::new();
    let mut grid = Vec::with_capacity(lengths.len());
    for &len in lengths {
        let mut row = Vec::with_capacity(depths.len());
        for &depth in depths {
            let mut rng = Rng::new(seed ^ (len as u64) << 16
                ^ ((depth * 1000.0) as u64));
            let mut correct = 0usize;
            for _ in 0..n_samples {
                let s = niah_sample(&mut rng, &text, len, depth);
                let (ok, _) = score_sample(model, &s, odp);
                correct += ok as usize;
            }
            row.push(correct as f64 / n_samples as f64);
        }
        grid.push(row);
    }
    grid
}

/// CoT chain (GSM8K analogue, Tab. 9): `steps` sequential modadd
/// queries where each answer feeds the next; a chain scores only if
/// every step is answered correctly, so single-step degradation
/// compounds exactly like multi-step reasoning under quantization.
pub fn eval_cot_chain(model: &MoeModel, steps: usize, n_chains: usize,
                      seed: u64, odp: Option<&OdpPolicy>) -> f64 {
    use crate::config::{BOS, NUM_BASE, NUM_COUNT, SEP, TASK_BASE};
    let mut rng = Rng::new(seed);
    let mut correct_chains = 0usize;
    let mut lp = Vec::new();
    for _ in 0..n_chains {
        let mut acc = rng.below(NUM_COUNT as usize) as u32;
        let mut all_ok = true;
        for _ in 0..steps {
            let b = rng.below(NUM_COUNT as usize) as u32;
            let want = (acc + b) % NUM_COUNT;
            let prompt = vec![BOS, TASK_BASE + 3, NUM_BASE + acc, NUM_BASE + b, SEP];
            let opts = ForwardOpts { odp, ..Default::default() };
            let out = model.forward(&prompt, &opts, &mut NullSink);
            log_softmax_into(out.logits.row(prompt.len() - 1), &mut lp);
            // argmax over the full number range (harder than 4-way MC)
            let pred = (0..NUM_COUNT)
                .max_by(|&a, &b| {
                    lp[(NUM_BASE + a) as usize]
                        .partial_cmp(&lp[(NUM_BASE + b) as usize])
                        .unwrap()
                })
                .unwrap();
            if pred != want {
                all_ok = false;
                break;
            }
            acc = want; // teacher-forced chain: feed the correct value
        }
        correct_chains += all_ok as usize;
    }
    correct_chains as f64 / n_chains as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn random_model_near_chance() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let report = eval_suite(&model, 10, 0, 42, None);
        assert_eq!(report.rows.len(), 8);
        // untrained: accuracy should hover near 25% (4-way chance)
        assert!(
            (0.05..0.6).contains(&report.average),
            "avg {}",
            report.average
        );
    }

    #[test]
    fn suite_deterministic() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 1);
        let a = eval_suite(&model, 5, 0, 7, None);
        let b = eval_suite(&model, 5, 0, 7, None);
        assert_eq!(a.rows, b.rows);
    }

    #[test]
    fn fewshot_prompts_run() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 2);
        let (acc, _) = eval_task(&model, 3, 5, 2, 9, None);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn niah_grid_shape() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 3);
        let grid = eval_niah_grid(&model, &[32, 48], &[0.0, 0.5, 1.0], 3, 11, None);
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].len(), 3);
        for row in &grid {
            for &v in row {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn cot_chain_bounds() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 4);
        let acc1 = eval_cot_chain(&model, 1, 10, 13, None);
        let acc4 = eval_cot_chain(&model, 4, 10, 13, None);
        assert!((0.0..=1.0).contains(&acc1));
        // longer chains cannot be easier
        assert!(acc4 <= acc1 + 1e-9);
    }
}
