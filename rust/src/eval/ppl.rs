//! Perplexity evaluation (the paper's WikiText2 metric, Tab. 7 / Figs.
//! 5-8): teacher-forced NLL over held-out streams of a chosen split.

use crate::data::{pack_stream, Split, TextChannel};
use crate::moe::model::{ForwardOpts, MoeModel, NullSink, OdpPolicy, RunStats};
use crate::tensor::log_softmax_into;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct PplReport {
    pub ppl: f64,
    pub tokens: usize,
    pub stats: RunStats,
}

/// Evaluate PPL over `n_seqs` held-out sequences of length `seq_len`.
/// `seed` controls the held-out stream (distinct from calibration seeds
/// by convention: calibration uses seeds < 1000, eval >= 1000).
pub fn perplexity(model: &MoeModel, split: Split, seed: u64, n_seqs: usize,
                  seq_len: usize, odp: Option<&OdpPolicy>) -> PplReport {
    let mut rng = Rng::new(seed);
    let text = TextChannel::new();
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
    let mut lp = Vec::new();
    for _ in 0..n_seqs {
        let toks = pack_stream(&mut rng, &text, seq_len, split);
        let opts = ForwardOpts { odp, ..Default::default() };
        let out = model.forward(&toks, &opts, &mut NullSink);
        stats.merge(&out.stats);
        for t in 1..toks.len() {
            log_softmax_into(out.logits.row(t - 1), &mut lp);
            nll -= lp[toks[t] as usize] as f64;
            count += 1;
        }
    }
    PplReport { ppl: (nll / count.max(1) as f64).exp(), tokens: count, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn random_model_ppl_near_uniform() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let r = perplexity(&model, Split::Text, 1000, 2, 48, None);
        // untrained model: ppl within a factor ~3 of |V| (logits are
        // random but embeddings induce some structure)
        assert!(r.ppl > 30.0 && r.ppl < 2000.0, "{}", r.ppl);
        assert_eq!(r.tokens, 2 * 47);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 1);
        let a = perplexity(&model, Split::General, 1001, 2, 32, None);
        let b = perplexity(&model, Split::General, 1001, 2, 32, None);
        assert_eq!(a.ppl, b.ppl);
        let c = perplexity(&model, Split::General, 1002, 2, 32, None);
        assert_ne!(a.ppl, c.ppl);
    }

    #[test]
    fn odp_stats_flow_through() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 2);
        let policy = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let r = perplexity(&model, Split::General, 1003, 1, 32, Some(&policy));
        assert!(r.stats.compression_ratio() > 0.4);
    }
}
