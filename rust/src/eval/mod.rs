//! Evaluation harness (EleutherAI-LM-Harness analogue, DESIGN.md §2):
//! perplexity, the 8-task zero/few-shot multiple-choice suite, NIAH
//! long-context retrieval, and the CoT-chain stress test.

pub mod ppl;
pub mod suite;

pub use ppl::{perplexity, PplReport};
pub use suite::{eval_cot_chain, eval_niah_grid, eval_suite, eval_task, SuiteReport};
