//! HTTP front-end soak bench (EXPERIMENTS.md §Serve): a burst of
//! concurrent streamed clients against a real `HttpServer` socket,
//! measuring admission behavior under overload and streaming latency
//! for the admitted set, then a graceful-drain phase that proves no
//! in-flight token is lost.
//!
//!   cargo bench --bench serve_soak              # 512 clients
//!   MC_BENCH_FAST=1 cargo bench --bench serve_soak   # 256, CI smoke
//!
//! Emits `BENCH_serve.json`: admitted/shed/completed/wedged counts,
//! p50/p99 TTFT and TPOT over the admitted streams, end-to-end token
//! throughput, and the drain report (validated by CI bench-smoke).

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::Server;
use mc_moe::serve::client::{self, GenerateReply};
use mc_moe::serve::{HttpServer, ServeConfig};

#[path = "../tests/common/mod.rs"]
mod common;
use common::random_model;

fn fast() -> bool {
    std::env::var("MC_BENCH_FAST").is_ok()
}

/// Per-read client bound: a stream stalled past this counts as wedged.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

/// One client's outcome in the burst phase.
enum Outcome {
    /// stream completed: (ttft_ms, tpot_ms, tokens)
    Completed(f64, f64, usize),
    /// 429 with a numeric Retry-After
    Shed,
    /// tenant-cap 429 (distinguished by the response body)
    TenantLimited,
    /// io error, timeout, missing Retry-After, or a broken stream
    Wedged(String),
}

fn run_client(addr: std::net::SocketAddr, idx: usize, max_new: usize)
              -> Outcome {
    let priority = ["low", "normal", "high"][idx % 3];
    let tenant = format!("tenant-{}", idx % 4);
    let body = format!(
        "{{\"prompt\":[1,5,{},3],\"max_new_tokens\":{max_new},\
         \"stop\":\"max_len\",\"priority\":\"{priority}\"}}",
        80 + idx % 8
    );
    let t0 = Instant::now();
    let reply = match client::open_generate(
        addr, body.as_bytes(), &[("X-Tenant", &tenant)], CLIENT_TIMEOUT)
    {
        Ok(r) => r,
        Err(e) => return Outcome::Wedged(format!("open: {e}")),
    };
    let mut stream = match reply {
        GenerateReply::Stream(s) => s,
        GenerateReply::Response(r) => {
            if r.status != 429 {
                return Outcome::Wedged(format!("status {}", r.status));
            }
            match r.header("retry-after").map(str::parse::<u64>) {
                Some(Ok(secs)) if secs >= 1 => {}
                _ => return Outcome::Wedged("429 without Retry-After".into()),
            }
            return if r.body_str().contains("tenant") {
                Outcome::TenantLimited
            } else {
                Outcome::Shed
            };
        }
    };
    let mut ttft_ms = 0.0;
    let mut first_token = None;
    let mut last_token = t0;
    let mut tokens = 0usize;
    loop {
        match stream.next_event() {
            Ok(Some(ev)) => match ev.name.as_str() {
                "token" => {
                    let now = Instant::now();
                    if first_token.is_none() {
                        ttft_ms = now.duration_since(t0).as_secs_f64() * 1e3;
                        first_token = Some(now);
                    }
                    last_token = now;
                    tokens += 1;
                }
                "done" => break,
                other => return Outcome::Wedged(format!("event {other:?}")),
            },
            Ok(None) => {
                return Outcome::Wedged("closed without done".into())
            }
            Err(e) => return Outcome::Wedged(format!("read: {e}")),
        }
    }
    if tokens != max_new {
        return Outcome::Wedged(format!("{tokens}/{max_new} tokens"));
    }
    let tpot_ms = match first_token {
        Some(f) if tokens > 1 => {
            last_token.duration_since(f).as_secs_f64() * 1e3
                / (tokens - 1) as f64
        }
        _ => 0.0,
    };
    Outcome::Completed(ttft_ms, tpot_ms, tokens)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = p * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

fn main() {
    let (clients, max_new) = if fast() { (256, 8) } else { (512, 16) };
    let drain_streams = 8usize;
    let cfg = ServeConfig {
        port: 0,
        max_conns: clients + 16,
        // unlimited per tenant: the burst measures queue shedding, and
        // admitted + shed must account for every client exactly
        max_streams_per_tenant: 0,
        shed_queue_depth: 64,
        max_batch: 8,
        ..ServeConfig::default()
    };
    let engine = Server::spawn(
        Arc::new(random_model(&ModelConfig::test_tiny(), 77)),
        None, cfg.max_batch);
    let http = HttpServer::bind(engine, cfg).expect("bind 127.0.0.1:0");
    let addr = http.addr();
    println!(
        "serve soak: {clients} clients x {max_new} tokens on {addr} \
         (batch=8, shed-depth=64)"
    );

    // -- burst phase: every client fires at once --------------------
    let barrier = Arc::new(Barrier::new(clients));
    let t_start = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|i| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                run_client(addr, i, max_new)
            })
        })
        .collect();
    let outcomes: Vec<Outcome> =
        workers.into_iter().map(|w| w.join().expect("client thread")).collect();
    let wall_s = t_start.elapsed().as_secs_f64();

    let mut admitted = 0u64;
    let mut shed = 0u64;
    let mut tenant_limited = 0u64;
    let mut wedged = 0u64;
    let mut tokens_total = 0usize;
    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    for o in &outcomes {
        match o {
            Outcome::Completed(ttft, tpot, tokens) => {
                admitted += 1;
                tokens_total += tokens;
                ttfts.push(*ttft);
                if *tpot > 0.0 {
                    tpots.push(*tpot);
                }
            }
            Outcome::Shed => shed += 1,
            Outcome::TenantLimited => tenant_limited += 1,
            Outcome::Wedged(why) => {
                wedged += 1;
                eprintln!("WEDGED client: {why}");
            }
        }
    }
    // every admitted client ran to done with the full token count
    // (run_client reports anything else as wedged)
    let completed = admitted;
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    tpots.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // -- drain phase: in-flight streams survive a graceful drain ----
    let mut streams = Vec::new();
    for i in 0..drain_streams {
        let body = format!(
            "{{\"prompt\":[2,6,{},3],\"max_new_tokens\":{max_new},\
             \"stop\":\"max_len\"}}",
            70 + i
        );
        match client::open_generate(addr, body.as_bytes(), &[],
                                    CLIENT_TIMEOUT) {
            Ok(GenerateReply::Stream(mut s)) => {
                // wait until demonstrably decoding before the drain
                match s.next_event() {
                    Ok(Some(ev)) if ev.name == "token" => {
                        streams.push((s, 1usize))
                    }
                    other => panic!("drain stream {i} first frame: {other:?}"),
                }
            }
            other => panic!("drain stream {i} refused: {:?}", other.is_ok()),
        }
    }
    let drain_resp = client::request(addr, "POST", "/admin/drain", &[], b"",
                                     CLIENT_TIMEOUT)
        .expect("drain request");
    assert_eq!(drain_resp.status, 200);
    // a post-drain submission must be refused
    let refused = client::open_generate(
        addr, b"{\"prompt\":[1,5,80,3]}", &[], CLIENT_TIMEOUT);
    let refused_503 = matches!(refused,
                               Ok(GenerateReply::Response(ref r))
                               if r.status == 503);
    // every in-flight stream still delivers every promised token
    let mut drain_tokens = 0usize;
    for (mut s, mut count) in streams {
        loop {
            match s.next_event().expect("drain stream read") {
                Some(ev) if ev.name == "token" => count += 1,
                Some(ev) if ev.name == "done" => break,
                other => panic!("drain stream event: {other:?}"),
            }
        }
        drain_tokens += count;
    }
    let tokens_lost = drain_streams * max_new - drain_tokens;
    let report = http.shutdown();

    // -- report -----------------------------------------------------
    let toks_per_s = tokens_total as f64 / wall_s;
    let kernel = mc_moe::kernels::active().isa.name();
    println!("admitted={admitted} shed={shed} tenant_limited={tenant_limited} \
              wedged={wedged} completed={completed}");
    println!("ttft p50={:.2}ms p99={:.2}ms  tpot p50={:.3}ms p99={:.3}ms",
             percentile(&ttfts, 0.50), percentile(&ttfts, 0.99),
             percentile(&tpots, 0.50), percentile(&tpots, 0.99));
    println!("tokens={tokens_total} wall={wall_s:.2}s ({toks_per_s:.0} tok/s)");
    println!("drain: {} streams, {:.1}ms, tokens_lost={tokens_lost}, \
              post-drain 503={refused_503}",
             drain_streams, report.drain_ms);
    assert_eq!(wedged, 0, "soak must complete with zero wedged connections");
    assert!(refused_503, "draining server must 503 new work");
    assert_eq!(tokens_lost, 0, "drain must not lose in-flight tokens");
    assert_eq!(admitted + shed + tenant_limited, clients as u64,
               "every client is accounted for exactly once");

    let json = format!(
        "{{\n  \"mode\": \"{mode}\",\n  \"clients\": {clients},\n  \
         \"max_new_tokens\": {max_new},\n  \"admitted\": {admitted},\n  \
         \"shed\": {shed},\n  \"tenant_limited\": {tenant_limited},\n  \
         \"completed\": {completed},\n  \"wedged\": {wedged},\n  \
         \"ttft_ms\": {{\"p50\": {tf50:.3}, \"p99\": {tf99:.3}}},\n  \
         \"tpot_ms\": {{\"p50\": {tp50:.4}, \"p99\": {tp99:.4}}},\n  \
         \"tokens_total\": {tokens_total},\n  \"wall_s\": {wall_s:.3},\n  \
         \"toks_per_s\": {toks_per_s:.1},\n  \
         \"drain\": {{\"inflight\": {drain_streams}, \
         \"drain_ms\": {dms:.2}, \"tokens_lost\": {tokens_lost}}},\n  \
         \"kernel_backend\": \"{kernel}\"\n}}\n",
        mode = if fast() { "fast" } else { "full" },
        tf50 = percentile(&ttfts, 0.50),
        tf99 = percentile(&ttfts, 0.99),
        tp50 = percentile(&tpots, 0.50),
        tp99 = percentile(&tpots, 0.99),
        dms = report.drain_ms,
    );
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
