//! Flight-recorder overhead gate (EXPERIMENTS.md §Trace): proves the
//! tracing instrumentation threaded through the decode hot path
//! (DESIGN.md §9) is free when disabled.
//!
//! Three measurements on the fused multi-session decode loop:
//!
//!   * `baseline`  — tracing never armed (the ring was never touched)
//!   * `disabled`  — tracing armed once, then disarmed: the steady
//!     state of a server that shipped with `--trace` support compiled
//!     in but off. Every instrumentation site costs one relaxed
//!     atomic load and a branch.
//!   * `enabled`   — full recording, reported for context (not gated)
//!
//! Baseline and disabled batches are interleaved A/B/A/B so thermal
//! and frequency drift cancel; the gate compares medians and passes
//! when disabled decode is within 1% of baseline (up to three
//! attempts, since a 1% gate on a shared CI box is noise-sensitive).
//! A microbench of the disarmed fast path (ns per `instant` call and
//! per `span` create+drop) plus a per-token call-count estimate gives
//! a second, analytical bound on the same claim.
//!
//!   cargo bench --bench trace_overhead            # full shapes
//!   MC_BENCH_FAST=1 cargo bench --bench trace_overhead  # CI smoke
//!
//! Emits `BENCH_trace.json` (validated by CI bench-smoke).

use std::sync::Arc;
use std::time::Instant;

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::decode::{step_many_into, StepScratch};
use mc_moe::coordinator::DecodeSession;
use mc_moe::moe::MoeModel;
use mc_moe::obs;

#[path = "../tests/common/mod.rs"]
mod common;
use common::random_model;

fn fast() -> bool {
    std::env::var("MC_BENCH_FAST").is_ok()
}

fn bench_cfg() -> ModelConfig {
    if fast() {
        ModelConfig {
            name: "trace-fast".into(),
            vocab_size: 256,
            d_model: 48,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            n_experts: 8,
            top_k: 2,
            max_seq: 64,
            prefill_tile: 32,
        }
    } else {
        ModelConfig {
            name: "trace".into(),
            vocab_size: 256,
            d_model: 96,
            n_layers: 4,
            n_heads: 4,
            d_ff: 384,
            n_experts: 8,
            top_k: 2,
            max_seq: 192,
            prefill_tile: 64,
        }
    }
}

/// One decode batch: fresh sessions, warmup step, then `steps` timed
/// fused steps. Returns ns per generated token.
fn decode_batch(model: &Arc<MoeModel>, batch: usize, prompt_len: usize,
                steps: usize) -> f64 {
    let mut sessions: Vec<DecodeSession> = (0..batch)
        .map(|i| {
            let mut s = DecodeSession::new(model.clone(), None);
            let prompt: Vec<u32> = (0..prompt_len)
                .map(|t| ((t * 7 + i) % 200 + 1) as u32)
                .collect();
            s.prefill(&prompt);
            s
        })
        .collect();
    let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
    let toks: Vec<u32> = (0..batch).map(|i| (i % 200 + 1) as u32).collect();
    let mut sc = StepScratch::new();
    step_many_into(&mut refs, &toks, &mut sc); // warmup: grow scratch
    let t0 = Instant::now();
    for _ in 0..steps {
        std::hint::black_box(step_many_into(&mut refs, &toks, &mut sc));
    }
    t0.elapsed().as_nanos() as f64 / (batch * steps) as f64
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// ns per call of the disarmed fast path (relaxed load + branch).
fn disarmed_call_ns() -> (f64, f64) {
    assert!(!obs::enabled(), "microbench must run disarmed");
    let n = 4_000_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        obs::instant(obs::Cat::Decode, "noop",
                     obs::args1("i", std::hint::black_box(i)));
    }
    let instant_ns = t0.elapsed().as_nanos() as f64 / n as f64;
    let t0 = Instant::now();
    for i in 0..n {
        let sp = obs::span(obs::Cat::Decode, "noop")
            .arg("i", std::hint::black_box(i));
        std::hint::black_box(&sp);
    }
    let span_ns = t0.elapsed().as_nanos() as f64 / n as f64;
    (instant_ns, span_ns)
}

/// Spin a real HTTP server on an offloaded model, run one request
/// with tracing armed over the wire, and save the `/debug/trace`
/// body as `trace_sample.json` — CI bench-smoke validates that the
/// stage chain (admission → queue → prefill → decode → expert fetch)
/// is present in a trace captured from a live server.
fn live_trace_sample() {
    use mc_moe::coordinator::Server;
    use mc_moe::moe::qz;
    use mc_moe::offload::{self, PrefetchMode};
    use mc_moe::serve::{client, HttpServer, ServeConfig};

    let cfg = ModelConfig::test_tiny();
    let m = random_model(&cfg, 51);
    let path = std::env::temp_dir()
        .join(format!("trace_sample_{}.mcqz", std::process::id()));
    qz::save(&path, &m).expect("save sample model");
    let expert_bytes: usize = m.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();
    drop(m);
    // half budget, no prefetch: demand expert fetches land in the trace
    let cached = offload::load_cached(&path, expert_bytes / 2,
                                      PrefetchMode::Off).expect("open");
    let engine = Server::spawn(Arc::new(cached), None, 2);
    let http = HttpServer::bind(engine, ServeConfig {
        port: 0,
        max_conns: 4,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 2,
        ..ServeConfig::default()
    }).expect("bind 127.0.0.1:0");
    let t = std::time::Duration::from_secs(120);

    client::request(http.addr(), "GET", "/debug/trace?enable=1&clear=1",
                    &[], b"", t).expect("arm tracing");
    let body = b"{\"prompt\":[1,5,80,3],\"max_new_tokens\":8,\
                 \"stop\":\"max_len\",\"stream\":false}";
    let resp = client::request(http.addr(), "POST", "/v1/generate", &[],
                               body, t).expect("live request");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let trace = client::request(http.addr(), "GET", "/debug/trace", &[],
                                b"", t).expect("trace window");
    assert_eq!(trace.status, 200);
    match std::fs::write("trace_sample.json", trace.body_str()) {
        Ok(()) => println!("wrote trace_sample.json (live-request trace)"),
        Err(e) => eprintln!("could not write trace_sample.json: {e}"),
    }
    client::request(http.addr(), "GET", "/debug/trace?enable=0&clear=1",
                    &[], b"", t).expect("disarm tracing");
    let _ = http.shutdown();
    std::fs::remove_file(&path).ok();
}

fn main() {
    let cfg = bench_cfg();
    let model = Arc::new(random_model(&cfg, 11));
    let batch = 4usize;
    let prompt_len = 16usize.min(cfg.max_seq / 4);
    let steps = if fast() { 16 } else { 48.min(cfg.max_seq - prompt_len - 2) };
    let pairs = if fast() { 7usize } else { 11 };

    // -- analytical bound: disarmed call cost x calls per token ------
    let (instant_ns, span_ns) = disarmed_call_ns();
    // decode-path instrumentation sites per generated token: per
    // layer one enabled() check plus prefetch/fetch instants, plus
    // the per-token decode_step / token_sampled / sse_write sites
    let calls_per_token = (4 * cfg.n_layers + 8) as f64;

    // -- interleaved A/B: never-armed baseline vs armed-then-disarmed
    let mut attempt = 0usize;
    let (mut base_med, mut dis_med, mut diff) = (0.0f64, 0.0f64, f64::MAX);
    while attempt < 3 && diff > 0.01 {
        attempt += 1;
        let mut base: Vec<f64> = Vec::new();
        let mut dis: Vec<f64> = Vec::new();
        for _ in 0..pairs {
            // A: tracing has never been armed in this phase
            obs::set_enabled(false);
            base.push(decode_batch(&model, batch, prompt_len, steps));
            // arm + disarm: the ring exists, the env Once has run —
            // steady "compiled in but off" state
            obs::set_enabled(true);
            obs::set_enabled(false);
            obs::clear();
            dis.push(decode_batch(&model, batch, prompt_len, steps));
        }
        base_med = median(&mut base);
        dis_med = median(&mut dis);
        diff = (dis_med - base_med) / base_med;
        println!(
            "attempt {attempt}: baseline {:.0} ns/tok, disabled {:.0} \
             ns/tok, overhead {:+.3}%",
            base_med, dis_med, diff * 100.0
        );
    }

    // -- enabled mode, for context (and to prove the sites fire) -----
    obs::set_enabled(true);
    obs::clear();
    let en_ns = decode_batch(&model, batch, prompt_len, steps);
    let recorded = obs::snapshot(None).len();
    obs::set_enabled(false);
    obs::clear();
    assert!(recorded > 0,
            "enabled decode recorded no events — instrumentation is dead");
    let en_diff = (en_ns - base_med) / base_med;

    let bound = calls_per_token * instant_ns.max(span_ns) / base_med;
    println!(
        "disarmed fast path: instant {instant_ns:.2} ns, span {span_ns:.2} ns \
         x ~{calls_per_token:.0} calls/token -> bound {:.4}% of {:.0} ns/tok",
        bound * 100.0, base_med
    );
    println!(
        "enabled: {en_ns:.0} ns/tok ({:+.1}% vs baseline, {recorded} events)",
        en_diff * 100.0
    );

    let pass = diff <= 0.01;
    let json = format!(
        "{{\n  \"mode\": \"{mode}\",\n  \
         \"batch\": {batch},\n  \"steps\": {steps},\n  \"pairs\": {pairs},\n  \
         \"attempts\": {attempt},\n  \
         \"baseline_ns_per_token\": {base_med:.1},\n  \
         \"disabled_ns_per_token\": {dis_med:.1},\n  \
         \"enabled_ns_per_token\": {en_ns:.1},\n  \
         \"disabled_overhead_frac\": {diff:.5},\n  \
         \"enabled_overhead_frac\": {en_diff:.5},\n  \
         \"disarmed_instant_ns\": {instant_ns:.3},\n  \
         \"disarmed_span_ns\": {span_ns:.3},\n  \
         \"calls_per_token_est\": {calls_per_token:.0},\n  \
         \"analytical_bound_frac\": {bound:.6},\n  \
         \"enabled_events_recorded\": {recorded},\n  \
         \"gate_frac\": 0.01,\n  \"pass\": {pass}\n}}\n",
        mode = if fast() { "fast" } else { "full" },
    );
    match std::fs::write("BENCH_trace.json", &json) {
        Ok(()) => println!("wrote BENCH_trace.json"),
        Err(e) => eprintln!("could not write BENCH_trace.json: {e}"),
    }

    assert!(pass,
            "disabled tracing must cost <=1% decode throughput \
             (measured {:+.3}% after {attempt} attempts)",
            diff * 100.0);
    println!("trace overhead gate: PASS ({:+.3}% <= 1%)", diff * 100.0);

    live_trace_sample();
}
