//! Chaos soak (EXPERIMENTS.md §Chaos): the serve-soak client burst
//! replayed against a *faulted* stack — a byte-budgeted offloaded
//! model whose demand fetches suffer injected I/O errors, corrupted
//! segments, and latency spikes, plus connection workers that panic
//! mid-request — proving the fault-tolerance ladder (DESIGN.md §7)
//! end to end:
//!
//!   * zero wedged clients: every stream reaches a terminal SSE event
//!     (`done`, `error`, `cancelled`) or a complete HTTP status
//!   * zero crashes: the process survives every injected panic and
//!     still answers `/healthz` afterwards
//!   * clean recovery: with faults cleared the same server serves
//!     full-length streams again, no restart
//!
//!   cargo bench --bench chaos_soak               # 160 clients
//!   MC_BENCH_FAST=1 cargo bench --bench chaos_soak   # 64, CI smoke
//!
//! The fault plan comes from `MC_FAULTS` when set; otherwise the
//! bench installs its own aggressive plan (see `DEFAULT_PLAN`).
//! Emits `BENCH_chaos.json` (validated by CI bench-smoke).

use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::{Server, ServerConfig};
use mc_moe::moe::qz;
use mc_moe::offload::{self, FetchPolicy, PrefetchMode};
use mc_moe::serve::client::{self, GenerateReply};
use mc_moe::serve::{HttpServer, ServeConfig};
use mc_moe::util::faults::{self, FaultPlan};

#[path = "../tests/common/mod.rs"]
mod common;
use common::random_model;

fn fast() -> bool {
    std::env::var("MC_BENCH_FAST").is_ok()
}

/// Per-read client bound: a stream stalled past this counts as wedged.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

/// The plan installed when `MC_FAULTS` is unset: 8% fetch I/O errors,
/// 4% corrupted segments, 2ms latency spikes on 5% of fetches, 4% of
/// requests hit a worker panic, 10% of prefetches dropped, 5% of
/// memory-governor reservations refused as if the budget were gone
/// (surfacing as 503 + Retry-After, counted under `shed`).
const DEFAULT_PLAN: &str = "io_err=0.08,corrupt=0.04,delay_ms=2@0.05,\
                            panic=0.04,prefetch_drop=0.10,oom=0.05,\
                            seed=4242";

/// One client's outcome under chaos.
enum Outcome {
    /// stream (or `"stream":false` reply) delivered every token
    Completed(usize),
    /// terminal SSE `error`/`cancelled` frame (deadline / cancel):
    /// a *failed* stream, but a cleanly terminated one
    ErrorEvent,
    /// complete HTTP 5xx status (panic → 500, deadline → 504)
    Http5xx(u16),
    /// 429 (load shed) or 503 (memory refusal) with Retry-After
    Shed,
    /// io error, timeout, or a stream cut without a terminal frame —
    /// the one outcome the fault ladder must never produce
    Wedged(String),
}

fn run_client(addr: std::net::SocketAddr, idx: usize, max_new: usize)
              -> Outcome {
    // every 4th client takes the non-streaming path so the 504/500
    // status mapping is exercised alongside the SSE error frames
    let want_stream = idx % 4 != 3;
    let body = format!(
        "{{\"prompt\":[1,5,{},3],\"max_new_tokens\":{max_new},\
         \"stop\":\"max_len\",\"stream\":{want_stream}}}",
        80 + idx % 8
    );
    let reply = match client::open_generate(addr, body.as_bytes(), &[],
                                            CLIENT_TIMEOUT) {
        Ok(r) => r,
        Err(e) => return Outcome::Wedged(format!("open: {e}")),
    };
    let mut stream = match reply {
        GenerateReply::Stream(s) => s,
        GenerateReply::Response(r) => {
            return match r.status {
                200 => Outcome::Completed(max_new),
                // 429 = load shed, 503 = memory-governor refusal; both
                // carry Retry-After and both are clean backpressure
                429 | 503 => Outcome::Shed,
                500 | 504 => Outcome::Http5xx(r.status),
                other => Outcome::Wedged(format!("status {other}")),
            };
        }
    };
    let mut tokens = 0usize;
    loop {
        match stream.next_event() {
            Ok(Some(ev)) => match ev.name.as_str() {
                "token" => tokens += 1,
                "done" => break,
                "error" | "cancelled" => return Outcome::ErrorEvent,
                other => return Outcome::Wedged(format!("event {other:?}")),
            },
            Ok(None) => {
                return Outcome::Wedged("closed without terminal".into())
            }
            Err(e) => return Outcome::Wedged(format!("read: {e}")),
        }
    }
    if tokens != max_new {
        return Outcome::Wedged(format!("done after {tokens}/{max_new}"));
    }
    Outcome::Completed(tokens)
}

fn main() {
    let (clients, max_new) = if fast() { (64, 8) } else { (160, 12) };

    // faulted substrate: an offloaded model at half budget, with a
    // tight retry/quarantine policy so injected failures actually
    // reach the quarantine + degraded-dispatch rungs of the ladder
    let injected = std::env::var("MC_FAULTS").is_err();
    if injected {
        faults::install(Some(FaultPlan::parse(DEFAULT_PLAN).unwrap()));
    }
    let path = std::env::temp_dir()
        .join(format!("chaos_soak_{}.mcqz", std::process::id()));
    let seed_model = random_model(&ModelConfig::test_tiny(), 99);
    qz::save(&path, &seed_model).expect("save chaos model");
    let expert_bytes: usize = seed_model.layers.iter()
        .flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes())
        .sum();
    drop(seed_model);
    let model = offload::load_cached_with_policy(
        &path, expert_bytes / 2, PrefetchMode::Async,
        FetchPolicy {
            max_retries: 2,
            backoff: Duration::from_micros(200),
            quarantine: Duration::from_millis(50),
        })
        .expect("open chaos model");

    let serve_cfg = ServeConfig {
        port: 0,
        max_conns: clients + 16,
        max_streams_per_tenant: 0,
        shed_queue_depth: 256,
        max_batch: 8,
        default_timeout: Some(Duration::from_secs(60)),
        ..ServeConfig::default()
    };
    let engine = Server::spawn_cfg(
        Arc::new(model), None,
        ServerConfig {
            max_batch: serve_cfg.max_batch,
            stall_budget: Duration::from_secs(10),
            ..ServerConfig::default()
        });
    let http = HttpServer::bind(engine, serve_cfg).expect("bind 127.0.0.1:0");
    let addr = http.addr();
    let metrics = http.metrics();
    println!(
        "chaos soak: {clients} clients x {max_new} tokens on {addr} \
         (plan: {})",
        if injected { DEFAULT_PLAN } else { "MC_FAULTS" }
    );

    // -- chaos burst: every client fires at once --------------------
    let barrier = Arc::new(Barrier::new(clients));
    let t_start = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|i| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                run_client(addr, i, max_new)
            })
        })
        .collect();
    let outcomes: Vec<Outcome> =
        workers.into_iter().map(|w| w.join().expect("client thread")).collect();
    let wall_s = t_start.elapsed().as_secs_f64();

    let mut completed = 0u64;
    let mut error_events = 0u64;
    let mut http_5xx = 0u64;
    let mut shed = 0u64;
    let mut wedged = 0u64;
    let mut tokens_total = 0usize;
    for o in &outcomes {
        match o {
            Outcome::Completed(n) => {
                completed += 1;
                tokens_total += n;
            }
            Outcome::ErrorEvent => error_events += 1,
            Outcome::Http5xx(_) => http_5xx += 1,
            Outcome::Shed => shed += 1,
            Outcome::Wedged(why) => {
                wedged += 1;
                eprintln!("WEDGED client: {why}");
            }
        }
    }

    // -- survival: the process answers health after the storm -------
    let health = client::request(addr, "GET", "/healthz", &[], b"",
                                 CLIENT_TIMEOUT)
        .expect("healthz after chaos");
    assert_eq!(health.status, 200, "server must survive the fault storm");

    // -- recovery: faults off, quarantines lapse, full streams again
    faults::install(None);
    std::thread::sleep(Duration::from_millis(200)); // > quarantine
    let mut recovered_ok = 0u64;
    let recovery_clients = 4usize;
    for i in 0..recovery_clients {
        match run_client(addr, i * 4, max_new) {
            Outcome::Completed(_) => recovered_ok += 1,
            other => {
                let label = match other {
                    Outcome::ErrorEvent => "error event".to_string(),
                    Outcome::Http5xx(s) => format!("http {s}"),
                    Outcome::Shed => "shed".to_string(),
                    Outcome::Wedged(w) => format!("wedged: {w}"),
                    Outcome::Completed(_) => unreachable!(),
                };
                eprintln!("recovery client {i}: {label}");
            }
        }
    }

    let retries = metrics.expert_load_retries.load(Relaxed);
    let failures = metrics.expert_load_failures.load(Relaxed);
    let quarantined = metrics.experts_quarantined.load(Relaxed);
    let degraded = metrics.degraded_dispatches.load(Relaxed);
    let deadline = metrics.deadline_exceeded.load(Relaxed);
    let panics = metrics.panics_recovered.load(Relaxed);
    let report = http.shutdown();
    std::fs::remove_file(&path).ok();

    // -- report -----------------------------------------------------
    let kernel = mc_moe::kernels::active().isa.name();
    println!("completed={completed} error_events={error_events} \
              http_5xx={http_5xx} shed={shed} wedged={wedged}");
    println!("ladder: retries={retries} failures={failures} \
              quarantined={quarantined} degraded={degraded} \
              deadline_exceeded={deadline} panics_recovered={panics}");
    println!("recovery: {recovered_ok}/{recovery_clients} clean streams \
              after faults cleared");
    println!("tokens={tokens_total} wall={wall_s:.2}s drain={:.1}ms \
              drained={}",
             report.drain_ms, report.drained);

    assert_eq!(wedged, 0, "chaos soak must end with zero wedged clients");
    assert_eq!(completed + error_events + http_5xx + shed, clients as u64,
               "every client is accounted for exactly once");
    if injected {
        assert!(retries > 0,
                "an 8% fetch fault rate must exercise the retry path");
        assert_eq!(recovered_ok, recovery_clients as u64,
                   "all post-chaos streams must complete clean");
    }

    let json = format!(
        "{{\n  \"mode\": \"{mode}\",\n  \"clients\": {clients},\n  \
         \"max_new_tokens\": {max_new},\n  \"completed\": {completed},\n  \
         \"error_events\": {error_events},\n  \"http_5xx\": {http_5xx},\n  \
         \"shed\": {shed},\n  \"wedged\": {wedged},\n  \
         \"recovered_ok\": {recovered_ok},\n  \
         \"recovery_clients\": {recovery_clients},\n  \
         \"injected_plan\": {plan},\n  \
         \"ladder\": {{\"expert_load_retries\": {retries}, \
         \"expert_load_failures\": {failures}, \
         \"experts_quarantined\": {quarantined}, \
         \"degraded_dispatches\": {degraded}, \
         \"deadline_exceeded\": {deadline}, \
         \"panics_recovered\": {panics}}},\n  \
         \"tokens_total\": {tokens_total},\n  \
         \"wall_s\": {wall_s:.3},\n  \
         \"drain_ms\": {dms:.2},\n  \
         \"kernel_backend\": \"{kernel}\"\n}}\n",
        mode = if fast() { "fast" } else { "full" },
        plan = if injected {
            format!("\"{DEFAULT_PLAN}\"")
        } else {
            "\"MC_FAULTS\"".to_string()
        },
        dms = report.drain_ms,
    );
    match std::fs::write("BENCH_chaos.json", &json) {
        Ok(()) => println!("wrote BENCH_chaos.json"),
        Err(e) => eprintln!("could not write BENCH_chaos.json: {e}"),
    }
}
