//! KV-pressure soak (EXPERIMENTS.md §KV pressure): a long-context
//! client burst against a server whose memory budget is ~50% of the
//! burst's worst-case KV footprint, proving the memory governor
//! (DESIGN.md §8) degrades instead of OOM-ing:
//!
//!   * zero aborted clients: every over-budget refusal is a clean
//!     503 + Retry-After, and retrying clients all finish
//!   * the full degradation ladder is observed: prefetch pause,
//!     expert-budget shrink, idle-prefix eviction, KV page
//!     down-quantization, and admission refusals all count > 0
//!   * clean recovery: once the storm passes, pressure returns to
//!     rung 0 and a reference request reproduces its pre-storm
//!     tokens bit-exactly
//!
//!   cargo bench --bench kv_pressure              # 24 clients
//!   MC_BENCH_FAST=1 cargo bench --bench kv_pressure  # 12, CI smoke
//!
//! Emits `BENCH_kvpressure.json` (validated by CI bench-smoke).

use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::memgov::{
    scratch_estimate_bytes, worst_case_kv_bytes,
};
use mc_moe::coordinator::{MemReservation, Server, ServerConfig};
use mc_moe::moe::exec::DEFAULT_PAGE_ROWS;
use mc_moe::moe::qz;
use mc_moe::offload::{self, FetchPolicy, PrefetchMode};
use mc_moe::serve::client;
use mc_moe::serve::{HttpServer, ServeConfig};

#[path = "../tests/common/mod.rs"]
mod common;
use common::random_model;

fn fast() -> bool {
    std::env::var("MC_BENCH_FAST").is_ok()
}

const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);
/// Refusal retries per client before the client counts as aborted —
/// generous: aborting is exactly what the governor must prevent.
const MAX_ATTEMPTS: usize = 400;

/// Long-context config: four 64-row pages of KV per session.
fn pressure_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::test_tiny();
    cfg.max_seq = 256;
    cfg
}

/// The deterministic part of a completion body (id / ttft_ms /
/// total_ms legitimately vary per request).
fn tokens_of(body: &str) -> String {
    let start = body.find("\"tokens\":[").expect("tokens array");
    let end = body[start..].find(']').expect("closing bracket") + start;
    body[start..=end].to_string()
}

/// One non-streaming request, retrying on 429/503 backpressure until
/// it completes. Returns (attempts_used, tokens_json) or an error
/// string describing the abort.
fn run_client(addr: std::net::SocketAddr, prompt: &[u32], max_new: usize)
              -> Result<(usize, String), String> {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let body = format!(
        "{{\"prompt\":[{}],\"max_new_tokens\":{max_new},\
         \"stop\":\"max_len\",\"stream\":false}}",
        toks.join(",")
    );
    for attempt in 1..=MAX_ATTEMPTS {
        let reply = client::request(addr, "POST", "/v1/generate", &[],
                                    body.as_bytes(), CLIENT_TIMEOUT)
            .map_err(|e| format!("transport: {e}"))?;
        match reply.status {
            200 => return Ok((attempt, tokens_of(&reply.body_str()))),
            429 | 503 => {
                if reply.header("retry-after").is_none() {
                    return Err(format!("{} without Retry-After",
                                       reply.status));
                }
                // honor the backoff signal at bench (not wall-clock)
                // scale so the soak finishes in seconds, not minutes
                std::thread::sleep(Duration::from_millis(25));
            }
            other => {
                return Err(format!("status {other}: {}",
                                   reply.body_str()))
            }
        }
    }
    Err(format!("aborted after {MAX_ATTEMPTS} refusals"))
}

fn main() {
    let (clients, max_new) = if fast() { (12usize, 24usize) } else { (24, 24) };
    let cfg = pressure_cfg();

    // offloaded substrate at half expert budget so the rung-1/2
    // actions (prefetch pause, budget shrink) act on a real cache
    let path = std::env::temp_dir()
        .join(format!("kv_pressure_{}.mcqz", std::process::id()));
    let seed_model = random_model(&cfg, 77);
    qz::save(&path, &seed_model).expect("save pressure model");
    let expert_bytes: usize = seed_model.layers.iter()
        .flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes())
        .sum();
    drop(seed_model);
    let expert_budget = expert_bytes / 2;
    let model = offload::load_cached_with_policy(
        &path, expert_budget, PrefetchMode::Async,
        FetchPolicy {
            max_retries: 2,
            backoff: Duration::from_micros(200),
            quarantine: Duration::from_millis(50),
        })
        .expect("open pressure model");

    // budget: static baseline + HALF the burst's worst-case KV bill
    let max_batch = 8usize;
    let unique_len = 176usize; // 2 cold pages even with 16 rows protected
    let shared_len = 160usize;
    let worst_kv = worst_case_kv_bytes(unique_len + max_new, 0,
                                       DEFAULT_PAGE_ROWS, cfg.n_layers,
                                       cfg.d_model);
    let static_bytes =
        expert_budget as u64 + scratch_estimate_bytes(&cfg, max_batch);
    let budget = static_bytes + clients as u64 * worst_kv / 2;

    let engine = Server::spawn_cfg(
        Arc::new(model), None,
        ServerConfig {
            max_batch,
            mem_budget: Some(budget),
            ..ServerConfig::default()
        });
    let gov = engine.governor().clone();
    let http = HttpServer::bind(engine, ServeConfig {
        port: 0,
        max_conns: clients + 8,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch,
        ..ServeConfig::default()
    }).expect("bind 127.0.0.1:0");
    let addr = http.addr();
    let metrics = http.metrics();
    println!(
        "kv pressure: {clients} clients x ~{unique_len}+{max_new} tokens, \
         budget {:.2} MiB (~50% of worst case) on {addr}",
        budget as f64 / (1 << 20) as f64
    );

    // -- pre-storm reference: the bit-exactness baseline -------------
    let reference_prompt: Vec<u32> =
        (0..40).map(|i| 3 + (i * 11 % 89) as u32).collect();
    let (_, ref_before) = run_client(addr, &reference_prompt, 8)
        .expect("pre-storm reference");

    // -- the storm: half identical prompts (prefix-sharing path), ----
    // -- half unique long prompts (down-quantization path) -----------
    let barrier = Arc::new(Barrier::new(clients + 1));
    let t_start = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|i| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let prompt: Vec<u32> = if i % 2 == 0 {
                    // identical long prompt: sessions share its prefix
                    (0..shared_len).map(|t| 1 + (t * 7 % 97) as u32).collect()
                } else {
                    // unique tail: a private long context per session
                    (0..unique_len)
                        .map(|t| 1 + ((t * 13 + i * 31) % 101) as u32)
                        .collect()
                };
                barrier.wait();
                run_client(addr, &prompt, max_new)
            })
        })
        .collect();
    barrier.wait();

    // -- pressure probe: once sessions are decoding, push reserved ---
    // -- bytes over the top rung so the whole ladder provably fires --
    let wait_deadline = Instant::now() + Duration::from_secs(30);
    while gov.bytes_reserved() <= static_bytes
        && Instant::now() < wait_deadline
    {
        std::thread::sleep(Duration::from_millis(2));
    }
    let target = (gov.budget_bytes() as f64 * 0.97) as u64;
    let mut probe: Vec<MemReservation> = Vec::new();
    let probe_deadline = Instant::now() + Duration::from_secs(8);
    while gov.bytes_reserved() < target && Instant::now() < probe_deadline {
        let mut chunk = target.saturating_sub(gov.bytes_reserved());
        let mut got = None;
        while chunk > 1024 {
            if let Some(r) = gov.try_reserve(chunk) {
                got = Some(r);
                break;
            }
            chunk /= 2;
        }
        match got {
            Some(r) => probe.push(r),
            None => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    let peak_pressure = gov.pressure();
    // hold across enough decode steps for rung-3 KV compression to
    // visit the active long-context sessions
    std::thread::sleep(Duration::from_millis(1200));
    drop(probe);

    let results: Vec<Result<(usize, String), String>> =
        workers.into_iter().map(|w| w.join().expect("client thread")).collect();
    let wall_s = t_start.elapsed().as_secs_f64();

    let mut completed = 0u64;
    let mut aborted = 0u64;
    let mut attempts_total = 0usize;
    for r in &results {
        match r {
            Ok((attempts, _)) => {
                completed += 1;
                attempts_total += attempts;
            }
            Err(why) => {
                aborted += 1;
                eprintln!("ABORTED client: {why}");
            }
        }
    }

    // -- recovery: pressure lifts, and the reference request ---------
    // -- reproduces its pre-storm tokens bit-exactly -----------------
    let (_, ref_after) = run_client(addr, &reference_prompt, 8)
        .expect("post-storm reference");
    let bit_exact = ref_after == ref_before;
    let final_rung = gov.rung();

    let pauses = metrics.mem_prefetch_pauses.load(Relaxed);
    let shrinks = metrics.mem_budget_shrinks.load(Relaxed);
    let evicted = metrics.kv_pages_evicted.load(Relaxed);
    let downq = metrics.kv_pages_downquantized.load(Relaxed);
    let refused = metrics.mem_admission_rejected.load(Relaxed);
    let published = metrics.kv_prefix_published.load(Relaxed);
    let hits = metrics.kv_prefix_hits.load(Relaxed);
    let report = http.shutdown();
    std::fs::remove_file(&path).ok();

    // -- report -----------------------------------------------------
    let kernel = mc_moe::kernels::active().isa.name();
    println!("completed={completed} aborted={aborted} \
              attempts_total={attempts_total} peak_pressure={peak_pressure:.3}");
    println!("ladder: prefetch_pauses={pauses} budget_shrinks={shrinks} \
              pages_evicted={evicted} pages_downquantized={downq} \
              admissions_refused={refused}");
    println!("prefix: published={published} hits={hits}");
    println!("recovery: rung={final_rung} reference_bit_exact={bit_exact} \
              wall={wall_s:.2}s drain={:.1}ms drained={}",
             report.drain_ms, report.drained);

    assert_eq!(aborted, 0, "pressure must degrade, never abort a client");
    assert_eq!(completed, clients as u64, "every client is accounted for");
    assert!(pauses > 0, "rung 1 (prefetch pause) never engaged");
    assert!(shrinks > 0, "rung 2 (expert-budget shrink) never engaged");
    assert!(evicted > 0, "rung 3 (idle-prefix eviction) never fired");
    assert!(downq > 0, "rung 3 (KV down-quantization) never fired");
    assert!(refused > 0, "the 50% budget never refused an admission");
    assert!(published > 0 && hits > 0,
            "identical prompts must publish and ride a shared prefix");
    assert!(bit_exact,
            "post-storm reference must reproduce pre-storm tokens");
    assert_eq!(final_rung, 0, "pressure must fully recover after the storm");

    let json = format!(
        "{{\n  \"mode\": \"{mode}\",\n  \"clients\": {clients},\n  \
         \"max_new_tokens\": {max_new},\n  \
         \"budget_bytes\": {budget},\n  \
         \"worst_case_session_bytes\": {worst_kv},\n  \
         \"completed\": {completed},\n  \"aborted\": {aborted},\n  \
         \"attempts_total\": {attempts_total},\n  \
         \"peak_pressure\": {peak_pressure:.4},\n  \
         \"ladder\": {{\"mem_prefetch_pauses\": {pauses}, \
         \"mem_budget_shrinks\": {shrinks}, \
         \"kv_pages_evicted\": {evicted}, \
         \"kv_pages_downquantized\": {downq}, \
         \"mem_admission_rejected\": {refused}}},\n  \
         \"prefix\": {{\"published\": {published}, \"hits\": {hits}}},\n  \
         \"reference_bit_exact\": {bit_exact},\n  \
         \"final_rung\": {final_rung},\n  \
         \"wall_s\": {wall_s:.3},\n  \
         \"drain_ms\": {dms:.2},\n  \
         \"kernel_backend\": \"{kernel}\"\n}}\n",
        mode = if fast() { "fast" } else { "full" },
        dms = report.drain_ms,
    );
    match std::fs::write("BENCH_kvpressure.json", &json) {
        Ok(()) => println!("wrote BENCH_kvpressure.json"),
        Err(e) => eprintln!("could not write BENCH_kvpressure.json: {e}"),
    }
}
