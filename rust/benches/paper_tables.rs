//! Regenerates every table and figure of the paper's evaluation on the
//! synthetic-substrate MoE model (DESIGN.md §10 experiment index).
//!
//!   cargo bench --bench paper_tables            # full run
//!   MC_FAST=1 cargo bench --bench paper_tables  # reduced samples
//!   MC_ONLY=tab2,fig6 cargo bench ...           # subset
//!
//! Absolute numbers differ from the paper (substrate: 3.5M-param
//! synthetic MoE vs Mixtral 8x7b); the *shapes* — method orderings,
//! crossovers, trade-off curves — are the reproduction target and are
//! recorded against the paper in EXPERIMENTS.md.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::coordinator::{memmodel, DecodeOdp, Server};
use mc_moe::data::{calibration_set, Split};
use mc_moe::eval::{eval_cot_chain, eval_niah_grid, eval_suite, perplexity};
use mc_moe::moe::model::{OdpPolicy, TokenMetric};
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::odp;
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::zoo::QuantBackend;
use mc_moe::pmq::{calibrate, Workbench, WorkbenchConfig};
use mc_moe::util::bench::Table;

struct Ctx {
    wb: Workbench,
    fast: bool,
    /// per-layer total-bit budgets swept (n..3n-ish, paper 1.57-2.54 avg)
    budgets: Vec<usize>,
    eval_samples: usize,
    ppl_seqs: usize,
}

impl Ctx {
    fn seq_len(&self) -> usize {
        self.wb.fp.cfg.max_seq
    }

    fn ppl_of(&self, m: &MoeModel, odp: Option<&OdpPolicy>) -> f64 {
        perplexity(m, Split::Text, 9000, self.ppl_seqs, self.seq_len(), odp).ppl
    }

    fn label(&self, total: usize) -> String {
        format!("{:.2}", total as f64 / self.wb.fp.cfg.n_experts as f64)
    }
}

fn load_ctx() -> Ctx {
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json"))
        .expect("run `make artifacts` first");
    let wf = WeightFile::load(&dir.join("weights.mcwt")).unwrap();
    let fp = MoeModel::load_f32(&cfg, wf).unwrap();
    let fast = std::env::var("MC_FAST").is_ok();
    let n = cfg.n_experts;
    eprintln!("[setup] building workbench (calibration, GPTQ zoo, probes)...");
    let t0 = Instant::now();
    let wb = Workbench::build(
        fp,
        WorkbenchConfig {
            calib_seqs: if fast { 4 } else { 8 },
            probe_seqs: if fast { 1 } else { 2 },
            fast_eps: false,
            ..Default::default()
        },
    )
    .unwrap();
    eprintln!("[setup] workbench ready in {:.1}s", t0.elapsed().as_secs_f64());
    let budgets: Vec<usize> = if fast {
        vec![n * 3 / 2, 2 * n, n * 5 / 2]
    } else {
        // n..=3n in steps of 1: avg 1.5 .. 2.5 plus extremes
        (n * 3 / 2..=n * 5 / 2).collect()
    };
    Ctx {
        wb,
        fast,
        budgets,
        eval_samples: if fast { 15 } else { 40 },
        ppl_seqs: if fast { 2 } else { 4 },
    }
}

fn want(section: &str) -> bool {
    match std::env::var("MC_ONLY") {
        Ok(only) => only.split(',').any(|s| s.trim() == section),
        Err(_) => true,
    }
}

// ---------------------------------------------------------------------------
// Fig. 3: expert significance heatmaps, general vs task-specific calib
// ---------------------------------------------------------------------------
fn fig3(ctx: &Ctx) {
    let mut t = Table::new(
        "Fig.3 — expert significance (general split): phi / weight / drop-Fnorm",
        &["layer", "phi (per expert)", "weight", "dropF"],
    );
    for l in 0..ctx.wb.fp.cfg.n_layers {
        let fmt = |v: &[f64]| {
            v.iter().map(|x| format!("{x:.2}")).collect::<Vec<_>>().join(" ")
        };
        let fmt32 = |v: &[f32]| {
            v.iter().map(|x| format!("{x:.2}")).collect::<Vec<_>>().join(" ")
        };
        t.row(vec![
            l.to_string(),
            fmt(&ctx.wb.sig.phi[l]),
            fmt(&ctx.wb.sig.weight[l]),
            fmt32(&ctx.wb.sig.drop_fnorm[l]),
        ]);
    }
    t.print();

    // task-specific (MATH-analogue) calibration: sparser activation
    let arith = calibration_set(31, if ctx.fast { 2 } else { 4 },
                                ctx.seq_len(), Split::Arith);
    let cal_a = calibrate(&ctx.wb.fp, &arith);
    let gini = |phi: &Vec<Vec<f64>>| -> f64 {
        // mean over layers of max/mean expert frequency (imbalance)
        let mut acc = 0.0;
        for row in phi {
            let mx = row.iter().cloned().fold(0.0, f64::max);
            let mean: f64 = row.iter().sum::<f64>() / row.len() as f64;
            acc += mx / mean.max(1e-9);
        }
        acc / phi.len() as f64
    };
    let g_gen = gini(&ctx.wb.sig.phi);
    let g_arith = gini(&cal_a.phi());
    println!(
        "\nFig.3 bottom: activation imbalance (max/mean phi) general={g_gen:.2} \
         arith={g_arith:.2} -> task-specific is {} concentrated (paper: sparser)",
        if g_arith > g_gen { "MORE" } else { "not more" }
    );
}

// ---------------------------------------------------------------------------
// Fig. 5 / Fig. 6: PPL vs avg bits for allocation strategies
// ---------------------------------------------------------------------------
fn fig5(ctx: &Ctx) {
    let mut t = Table::new(
        "Fig.5 — random allocation vs PMQ (PPL, lower=better)",
        &["avg bits", "random(min..max over seeds)", "PMQ"],
    );
    let seeds = if ctx.fast { 3 } else { 8 };
    for &b in &ctx.budgets {
        let mut rand_ppl = Vec::new();
        for s in 0..seeds {
            let (m, _) = ctx.wb
                .compress(Allocator::Random(s as u64 + 1), b, PmqHyper::default())
                .unwrap();
            rand_ppl.push(ctx.ppl_of(&m, None));
        }
        let (m, _) = ctx.wb.compress(Allocator::Pmq, b, PmqHyper::default()).unwrap();
        let pmq = ctx.ppl_of(&m, None);
        let lo = rand_ppl.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rand_ppl.iter().cloned().fold(0.0, f64::max);
        t.row(vec![ctx.label(b), format!("{lo:.2}..{hi:.2}"), format!("{pmq:.2}")]);
    }
    t.print();
}

fn fig6(ctx: &Ctx) {
    let mut t = Table::new(
        "Fig.6 — allocation metric ablation (PPL, lower=better)",
        &["avg bits", "weight", "freq", "hessian", "fnorm", "PMQ"],
    );
    for &b in &ctx.budgets {
        let mut cells = vec![ctx.label(b)];
        for strat in [
            Allocator::Weight,
            Allocator::Frequency,
            Allocator::Hessian,
            Allocator::FNorm,
            Allocator::Pmq,
        ] {
            let (m, _) = ctx.wb.compress(strat, b, PmqHyper::default()).unwrap();
            cells.push(format!("{:.2}", ctx.ppl_of(&m, None)));
        }
        t.row(cells);
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 2 / Tab. 5: zero-shot benchmark suite across methods/budgets
// ---------------------------------------------------------------------------
fn tab2(ctx: &Ctx) {
    let fp_suite = eval_suite(&ctx.wb.fp, ctx.eval_samples, 0, 4242, None);
    let mut t = Table::new(
        "Tab.2 — zero-shot suite (accuracy %, 4-way MC; chance=25)",
        &["method", "bits", "copy", "rev", "sort", "arith", "recall",
          "major", "count", "induc", "Avg"],
    );
    let mut row = |name: &str, bits: String, r: &mc_moe::eval::SuiteReport| {
        let mut cells = vec![name.to_string(), bits];
        for (_, _, acc) in &r.rows {
            cells.push(format!("{:.1}", acc * 100.0));
        }
        cells.push(format!("{:.2}", r.average * 100.0));
        t.row(cells);
    };
    row("FP32", "32".into(), &fp_suite);
    let n = ctx.wb.fp.cfg.n_experts;
    for bits in [3usize, 2] {
        let m = ctx.wb.compress_uniform(bits).unwrap();
        let r = eval_suite(&m, ctx.eval_samples, 0, 4242, None);
        row("Uni", format!("{bits}.00"), &r);
    }
    let budgets = if ctx.fast {
        vec![2 * n, n * 5 / 2]
    } else {
        vec![n * 3 / 2, 7 * n / 4, 2 * n, 9 * n / 4, n * 5 / 2]
    };
    for strat in [Allocator::Bsp, Allocator::Hessian, Allocator::Pmq] {
        for &b in &budgets {
            let (m, alloc) = ctx.wb.compress(strat, b, PmqHyper::default()).unwrap();
            let r = eval_suite(&m, ctx.eval_samples, 0, 4242, None);
            row(&format!("{strat:?}").split('(').next().unwrap().to_string(),
                format!("{:.2}", alloc.avg_bits()), &r);
        }
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 3 / Tab. 6: few-shot (MMLU-analogue = induction task, 5-shot)
// ---------------------------------------------------------------------------
fn tab3(ctx: &Ctx) {
    let mut t = Table::new(
        "Tab.3 — few-shot (induction 5-shot accuracy %)",
        &["method", "bits", "acc"],
    );
    let n = ctx.wb.fp.cfg.n_experts;
    let samples = ctx.eval_samples;
    let (fp_acc, _) = mc_moe::eval::eval_task(&ctx.wb.fp, 7, samples, 5, 77, None);
    t.row(vec!["FP32".into(), "32".into(), format!("{:.1}", fp_acc * 100.0)]);
    let m = ctx.wb.compress_uniform(2).unwrap();
    let (acc, _) = mc_moe::eval::eval_task(&m, 7, samples, 5, 77, None);
    t.row(vec!["Uni".into(), "2.00".into(), format!("{:.1}", acc * 100.0)]);
    for strat in [Allocator::Bsp, Allocator::Hessian, Allocator::Pmq] {
        for &b in &[n * 3 / 2, 2 * n, n * 5 / 2] {
            let (m, alloc) = ctx.wb.compress(strat, b, PmqHyper::default()).unwrap();
            let (acc, _) = mc_moe::eval::eval_task(&m, 7, samples, 5, 77, None);
            t.row(vec![format!("{strat:?}"), format!("{:.2}", alloc.avg_bits()),
                       format!("{:.1}", acc * 100.0)]);
        }
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 7: PPL across methods/budgets (WikiText2 analogue)
// ---------------------------------------------------------------------------
fn tab7(ctx: &Ctx) {
    let mut t = Table::new(
        "Tab.7 — text-split PPL (lower=better)",
        &["method", "bits", "PPL"],
    );
    t.row(vec!["FP32".into(), "32".into(),
               format!("{:.2}", ctx.ppl_of(&ctx.wb.fp, None))]);
    let m = ctx.wb.compress_uniform(2).unwrap();
    t.row(vec!["Uni".into(), "2.00".into(), format!("{:.2}", ctx.ppl_of(&m, None))]);
    for strat in [Allocator::Bsp, Allocator::Hessian, Allocator::Pmq] {
        for &b in &ctx.budgets {
            let (m, alloc) = ctx.wb.compress(strat, b, PmqHyper::default()).unwrap();
            t.row(vec![format!("{strat:?}"), format!("{:.2}", alloc.avg_bits()),
                       format!("{:.2}", ctx.ppl_of(&m, None))]);
        }
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Fig. 7 / Fig. 8: token protection and token-drop sweeps on the
// 2.0-avg-bit PMQ model
// ---------------------------------------------------------------------------
fn odp_ppl(ctx: &Ctx, m: &MoeModel, odp: Option<&OdpPolicy>)
    -> mc_moe::eval::PplReport {
    // general split: task answers make pruning damage visible
    perplexity(m, Split::General, 9000, ctx.ppl_seqs, ctx.seq_len(), odp)
}

fn fig7_fig8(ctx: &Ctx) {
    let n = ctx.wb.fp.cfg.n_experts;
    let (m, _) = ctx.wb.compress(Allocator::Pmq, 2 * n, PmqHyper::default()).unwrap();
    let mu = ctx.wb.cal.mu_median();

    let mut t = Table::new(
        "Fig.7 — protected-token ratio sweep (2.0-bit PMQ model)",
        &["protect %", "PPL", "CR %"],
    );
    // star row: weight-only pruning
    let wo = OdpPolicy::WeightOnly { mu: mu.clone() };
    let r = odp_ppl(ctx, &m, Some(&wo));
    t.row(vec!["weight-only".into(), format!("{:.2}", r.ppl),
               format!("{:.1}", r.stats.compression_ratio() * 100.0)]);
    for prot in [0.0f32, 0.02, 0.04, 0.08, 0.12, 0.16] {
        let p = OdpPolicy::Protected { mu: mu.clone(), protect_ratio: prot };
        let r = odp_ppl(ctx, &m, Some(&p));
        t.row(vec![format!("{:.0}", prot * 100.0), format!("{:.2}", r.ppl),
                   format!("{:.1}", r.stats.compression_ratio() * 100.0)]);
    }
    t.print();

    let mut t = Table::new(
        "Fig.8 — drop ALL experts of least-significant tokens",
        &["drop %", "PPL", "CR %"],
    );
    for drop in [0.0f32, 0.02, 0.04, 0.08, 0.12, 0.16] {
        let p = OdpPolicy::ProtectedDropAll {
            mu: mu.clone(),
            protect_ratio: 0.02,
            drop_ratio: drop,
        };
        let r = odp_ppl(ctx, &m, Some(&p));
        t.row(vec![format!("{:.0}", drop * 100.0), format!("{:.2}", r.ppl),
                   format!("{:.1}", r.stats.compression_ratio() * 100.0)]);
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 4: PMQ/ODP ablation — accuracy, memory, activated params, speedup
// ---------------------------------------------------------------------------
fn tab4(ctx: &Ctx) {
    let mut t = Table::new(
        "Tab.4 — PMQ x ODP ablation",
        &["config", "bits", "LM-Eval %", "Params GB", "ActParams MB/tok",
          "CR %", "decode tok/s", "speedup"],
    );
    let n = ctx.wb.fp.cfg.n_experts;
    let samples = ctx.eval_samples;
    // measured decode throughput via the KV-cache path
    let measure_tps = |m: &MoeModel, odp: Option<DecodeOdp>| -> f64 {
        let model = Arc::new(m.clone());
        let mut sess = mc_moe::coordinator::DecodeSession::new(model, odp);
        let t0 = Instant::now();
        let steps = if ctx.fast { 48 } else { 128 };
        for i in 0..steps {
            sess.step((i % 200 + 1) as u32);
        }
        steps as f64 / t0.elapsed().as_secs_f64()
    };
    let fp_tps = measure_tps(&ctx.wb.fp, None);
    let mut push = |name: &str, m: &MoeModel, odp: Option<&OdpPolicy>,
                    decode_odp: Option<DecodeOdp>, avg_bits: f64| {
        let r = eval_suite(m, samples, 0, 4242, odp);
        let keep = 1.0 - r.stats.compression_ratio();
        let tps = measure_tps(m, decode_odp);
        t.row(vec![
            name.into(),
            format!("{avg_bits:.2}"),
            format!("{:.2}", r.average * 100.0),
            format!("{:.4}", memmodel::gb(memmodel::loading_bytes(m))),
            format!("{:.3}",
                    memmodel::activated_bytes_per_token(m, keep) / (1 << 20) as f64),
            format!("{:.1}", r.stats.compression_ratio() * 100.0),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / fp_tps),
        ]);
    };
    push("FP32", &ctx.wb.fp, None, None, 32.0);
    let uni = ctx.wb.compress_uniform(2).unwrap();
    push("Uni-2bit", &uni, None, None, 2.0);
    let mu = ctx.wb.cal.mu_median();
    for &b in &[2 * n, n * 5 / 2] {
        let (m, alloc) = ctx.wb.compress(Allocator::Pmq, b, PmqHyper::default()).unwrap();
        push("PMQ", &m, None, None, alloc.avg_bits());
        let policy = odp::odp(&ctx.wb.cal, 0.02);
        let d = DecodeOdp { mu: mu.clone(), l1_threshold: None };
        push("PMQ+ODP", &m, Some(&policy), Some(d), alloc.avg_bits());
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 8: quantizer backend swap (GPTQ vs LWC/OmniQuant-style vs RTN)
// ---------------------------------------------------------------------------
fn tab8(ctx: &Ctx) {
    let mut t = Table::new(
        "Tab.8 — PMQ with different quantization backends",
        &["backend", "bits", "LM-Eval %", "PPL"],
    );
    let n = ctx.wb.fp.cfg.n_experts;
    for backend in [QuantBackend::Gptq, QuantBackend::Lwc, QuantBackend::Rtn] {
        let wb = Workbench::build(
            ctx.wb.fp.clone(),
            WorkbenchConfig {
                calib_seqs: if ctx.fast { 4 } else { 8 },
                probe_seqs: 1,
                fast_eps: true, // recon-proxy keeps backend comparison cheap
                backend,
                ..Default::default()
            },
        )
        .unwrap();
        for &b in &[2 * n, n * 5 / 2] {
            let (m, alloc) = wb.compress(Allocator::Pmq, b, PmqHyper::default()).unwrap();
            let r = eval_suite(&m, ctx.eval_samples, 0, 4242, None);
            t.row(vec![format!("{backend:?}"), format!("{:.2}", alloc.avg_bits()),
                       format!("{:.2}", r.average * 100.0),
                       format!("{:.2}", ctx.ppl_of(&m, None))]);
        }
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 9: challenging benchmarks (CoT chains + NIAH)
// ---------------------------------------------------------------------------
fn tab9(ctx: &Ctx) {
    let mut t = Table::new(
        "Tab.9 — challenging tasks",
        &["method", "bits", "CoT-x3 %", "NIAH %"],
    );
    let n = ctx.wb.fp.cfg.n_experts;
    let chains = if ctx.fast { 15 } else { 40 };
    let niah_n = if ctx.fast { 8 } else { 20 };
    let niah_avg = |m: &MoeModel, odp: Option<&OdpPolicy>| -> f64 {
        let g = eval_niah_grid(m, &[96, 192], &[0.25, 0.75], niah_n, 4242, odp);
        g.iter().flatten().sum::<f64>() / 4.0
    };
    let mut push = |name: &str, bits: String, m: &MoeModel, odp: Option<&OdpPolicy>| {
        t.row(vec![name.into(), bits,
                   format!("{:.1}", eval_cot_chain(m, 3, chains, 4242, odp) * 100.0),
                   format!("{:.1}", niah_avg(m, odp) * 100.0)]);
    };
    push("FP32", "32".into(), &ctx.wb.fp, None);
    let uni = ctx.wb.compress_uniform(2).unwrap();
    push("Uni", "2.00".into(), &uni, None);
    for strat in [Allocator::Bsp, Allocator::Hessian, Allocator::Pmq] {
        let (m, alloc) = ctx.wb.compress(strat, n * 5 / 2, PmqHyper::default()).unwrap();
        push(&format!("{strat:?}"), format!("{:.2}", alloc.avg_bits()), &m, None);
    }
    let (m, alloc) = ctx.wb.compress(Allocator::Pmq, n * 5 / 2, PmqHyper::default()).unwrap();
    let policy = odp::odp(&ctx.wb.cal, 0.02);
    push("PMQ+ODP", format!("{:.2}", alloc.avg_bits()), &m, Some(&policy));
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 10: alpha/beta hyper-parameter ablation (gamma=2)
// ---------------------------------------------------------------------------
fn tab10(ctx: &Ctx) {
    let mut t = Table::new(
        "Tab.10 — Eq.4 alpha/beta ablation (PPL at 2.0 avg bits, gamma=2)",
        &["alpha", "beta=1", "beta=1.5", "beta=2"],
    );
    let n = ctx.wb.fp.cfg.n_experts;
    for alpha in [1.0, 1.5, 2.0] {
        let mut cells = vec![format!("{alpha}")];
        for beta in [1.0, 1.5, 2.0] {
            let hyper = PmqHyper { alpha, beta, gamma: 2.0 };
            let (m, _) = ctx.wb.compress(Allocator::Pmq, 2 * n, hyper).unwrap();
            cells.push(format!("{:.2}", ctx.ppl_of(&m, None)));
        }
        t.row(cells);
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 11: token-dependent pruning metric comparison
// ---------------------------------------------------------------------------
fn tab11(ctx: &Ctx) {
    let n = ctx.wb.fp.cfg.n_experts;
    let (m, _) = ctx.wb.compress(Allocator::Pmq, 2 * n, PmqHyper::default()).unwrap();
    let mut t = Table::new(
        "Tab.11 — token-dependent pruning metrics (2.0-bit PMQ model)",
        &["method", "CR %", "PPL", "LM-Eval %"],
    );
    let mut push = |name: &str, policy: &OdpPolicy| {
        let r = odp_ppl(ctx, &m, Some(policy));
        let s = eval_suite(&m, ctx.eval_samples, 0, 4242, Some(policy));
        t.row(vec![name.into(),
                   format!("{:.1}", r.stats.compression_ratio() * 100.0),
                   format!("{:.2}", r.ppl),
                   format!("{:.2}", s.average * 100.0)]);
    };
    push("kurtosis", &odp::token_metric(TokenMetric::Kurtosis, 0.3));
    push("variance", &odp::token_metric(TokenMetric::Variance, 0.3));
    push("mean|t|", &odp::token_metric(TokenMetric::MeanAbs, 0.3));
    push("ODP", &odp::odp(&ctx.wb.cal, 0.02));
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 12: pruning threshold ablation
// ---------------------------------------------------------------------------
fn tab12(ctx: &Ctx) {
    let n = ctx.wb.fp.cfg.n_experts;
    let (m, _) = ctx.wb.compress(Allocator::Pmq, 2 * n, PmqHyper::default()).unwrap();
    let mut t = Table::new(
        "Tab.12 — threshold mu ablation",
        &["mu", "PPL", "pruned %"],
    );
    let nl = ctx.wb.fp.cfg.n_layers;
    for mu in [0.4f32, 0.5, 0.6, 0.7] {
        let p = odp::manual_threshold(nl, mu, None);
        let r = odp_ppl(ctx, &m, Some(&p));
        t.row(vec![format!("{mu}"), format!("{:.2}", r.ppl),
                   format!("{:.1}", r.stats.compression_ratio() * 100.0)]);
    }
    let median = odp::weight_only(&ctx.wb.cal);
    let r = odp_ppl(ctx, &m, Some(&median));
    t.row(vec!["median".into(), format!("{:.2}", r.ppl),
               format!("{:.1}", r.stats.compression_ratio() * 100.0)]);
    let full = odp::odp(&ctx.wb.cal, 0.02);
    let r = odp_ppl(ctx, &m, Some(&full));
    t.row(vec!["ODP(median+prot)".into(), format!("{:.2}", r.ppl),
               format!("{:.1}", r.stats.compression_ratio() * 100.0)]);
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 13: end-to-end latency grid (measured, native engine)
// ---------------------------------------------------------------------------
fn tab13(ctx: &Ctx) {
    let n = ctx.wb.fp.cfg.n_experts;
    let (mc, _) = ctx.wb.compress(Allocator::Pmq, n * 5 / 2, PmqHyper::default()).unwrap();
    let mu = ctx.wb.cal.mu_median();
    let mut t = Table::new(
        "Tab.13 — per-token decode latency (s), FP32 vs MC, [batch, prefill]",
        &["config", "[1,64]", "[1,128]", "[2,128]", "[4,128]"],
    );
    let cases = [(1usize, 64usize), (1, 128), (2, 128), (4, 128)];
    let mut measure = |name: &str, model: &MoeModel, odp: Option<DecodeOdp>| {
        let model = Arc::new(model.clone());
        let mut cells = vec![name.to_string()];
        for &(batch, prefill) in &cases {
            let server = Server::spawn(model.clone(), odp.clone(), batch);
            let decode = if ctx.fast { 16 } else { 32 };
            let mut rng = mc_moe::util::rng::Rng::new(7);
            let t0 = Instant::now();
            let handles: Vec<_> = (0..batch)
                .map(|_| {
                    let prompt: Vec<u32> =
                        (0..prefill).map(|_| rng.below(200) as u32 + 1).collect();
                    server.submit_greedy(prompt, decode)
                })
                .collect();
            for h in handles {
                let _ = h.wait();
            }
            let total_tokens = server
                .metrics
                .tokens_generated
                .load(Ordering::Relaxed) as f64;
            cells.push(format!("{:.4}", t0.elapsed().as_secs_f64() / total_tokens));
            server.shutdown();
        }
        t.row(cells);
    };
    measure("FP32", &ctx.wb.fp, None);
    measure("MC-2.5bit", &mc, None);
    measure("MC+ODP", &mc, Some(DecodeOdp { mu, l1_threshold: None }));
    t.print();
}

// ---------------------------------------------------------------------------
// Tab. 14: platform comparison (memory model + bandwidth estimates)
// ---------------------------------------------------------------------------
fn tab14(ctx: &Ctx) {
    let n = ctx.wb.fp.cfg.n_experts;
    let (mc, _) = ctx.wb.compress(Allocator::Pmq, n * 5 / 2, PmqHyper::default()).unwrap();
    let mut t = Table::new(
        "Tab.14 — platform feasibility (memory model, Mixtral-8x7b-scale extrapolation)",
        &["model", "platform", "load GB", "peak GB", "fits",
          "est tok/s (bw-bound)"],
    );
    // extrapolate our measured compression ratio to Mixtral-8x7b sizes
    let ratio = memmodel::loading_bytes(&mc) as f64
        / memmodel::loading_bytes(&ctx.wb.fp) as f64;
    let mixtral_fp32_gb = 96.8; // paper Tab. 14 loading memory
    for (name, gb) in [("Mixtral-8x7b FP16", mixtral_fp32_gb),
                       ("Mixtral-8x7b MC", mixtral_fp32_gb * ratio)] {
        for p in &memmodel::PLATFORMS[..2] {
            let fits = gb * 1.25 < p.mem_bytes as f64 / (1u64 << 30) as f64;
            // bandwidth-bound: activated share ~ 27% of total for 8x7b
            let act_gb = gb * 0.27;
            let tps = p.bw_bytes_per_s / (act_gb * (1u64 << 30) as f64);
            t.row(vec![name.into(), p.name.into(), format!("{gb:.1}"),
                       format!("{:.1}", gb * 1.25),
                       if fits { "yes".into() } else { "OOM".into() },
                       if fits { format!("{tps:.0}") } else { "-".into() }]);
        }
    }
    println!("(measured compression ratio on this substrate: {:.1}% of FP32)",
             ratio * 100.0);
    t.print();
}

// ---------------------------------------------------------------------------
// Fig. 9: NIAH heatmap; Fig. 10: allocation visualization; Fig. 1 frontier
// ---------------------------------------------------------------------------
fn fig9(ctx: &Ctx) {
    let n = ctx.wb.fp.cfg.n_experts;
    let (m, _) = ctx.wb.compress(Allocator::Pmq, n * 5 / 2, PmqHyper::default()).unwrap();
    let lengths = [64usize, 128, 192, 256];
    let depths = [0.1, 0.3, 0.5, 0.7, 0.9];
    let samples = if ctx.fast { 6 } else { 15 };
    for (name, model) in [("FP32", &ctx.wb.fp), ("PMQ-2.5bit", &m)] {
        let g = eval_niah_grid(model, &lengths, &depths, samples, 4242, None);
        println!("\nFig.9 — NIAH retrieval accuracy, {name} (rows=ctx len, cols=depth)");
        print!("{:>6}", "len");
        for d in depths {
            print!("{d:>6.1}");
        }
        println!();
        for (i, row) in g.iter().enumerate() {
            print!("{:>6}", lengths[i]);
            for v in row {
                print!("{:>6.2}", v);
            }
            println!();
        }
    }
}

fn fig10(ctx: &Ctx) {
    println!("\nFig.10 — PMQ bit allocation across budgets (rows=layer, cols=expert)");
    let n = ctx.wb.fp.cfg.n_experts;
    for &b in &[3 * n / 2, 2 * n, 5 * n / 2] {
        let (_, alloc) = ctx.wb.compress(Allocator::Pmq, b, PmqHyper::default()).unwrap();
        println!("avg {:.2} bits:", alloc.avg_bits());
        for row in &alloc.bits {
            let s: String = row.iter().map(|b| b.to_string()).collect();
            println!("  {s}");
        }
    }
}

fn fig1(ctx: &Ctx) {
    let mut t = Table::new(
        "Fig.1 — accuracy vs activated-parameter frontier",
        &["model", "act MB/tok", "LM-Eval %"],
    );
    let n = ctx.wb.fp.cfg.n_experts;
    let samples = ctx.eval_samples;
    let fp = eval_suite(&ctx.wb.fp, samples, 0, 4242, None);
    t.row(vec!["FP32 MoE".into(),
               format!("{:.3}", memmodel::activated_bytes_per_token(&ctx.wb.fp, 1.0)
                       / (1 << 20) as f64),
               format!("{:.2}", fp.average * 100.0)]);
    for &b in &[3 * n / 2, 2 * n, 5 * n / 2] {
        let (m, alloc) = ctx.wb.compress(Allocator::Pmq, b, PmqHyper::default()).unwrap();
        let policy = odp::odp(&ctx.wb.cal, 0.02);
        let r = eval_suite(&m, samples, 0, 4242, Some(&policy));
        let keep = 1.0 - r.stats.compression_ratio();
        t.row(vec![format!("MC {:.2}b+ODP", alloc.avg_bits()),
                   format!("{:.3}",
                           memmodel::activated_bytes_per_token(&m, keep)
                           / (1 << 20) as f64),
                   format!("{:.2}", r.average * 100.0)]);
    }
    t.print();
}

fn main() {
    let t0 = Instant::now();
    let ctx = load_ctx();
    let sections: Vec<(&str, fn(&Ctx))> = vec![
        ("fig3", fig3),
        ("fig5", fig5),
        ("fig6", fig6),
        ("tab2", tab2),
        ("tab3", tab3),
        ("tab7", tab7),
        ("fig7", fig7_fig8),
        ("tab4", tab4),
        ("tab8", tab8),
        ("tab9", tab9),
        ("tab10", tab10),
        ("tab11", tab11),
        ("tab12", tab12),
        ("tab13", tab13),
        ("tab14", tab14),
        ("fig9", fig9),
        ("fig10", fig10),
        ("fig1", fig1),
    ];
    for (name, f) in sections {
        if want(name) {
            let t = Instant::now();
            f(&ctx);
            eprintln!("[{name}] {:.1}s", t.elapsed().as_secs_f64());
        }
    }
    eprintln!("\n[paper_tables] total {:.1}s", t0.elapsed().as_secs_f64());
}
