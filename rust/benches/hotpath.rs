//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): tiled vs scalar
//! GEMM, packed dequant matmul vs dense f32, pooled vs serial
//! attention, expert dispatch (persistent pool vs legacy per-call
//! spawns vs serial), end-to-end fused multi-session decode, and the
//! artifact-gated engine paths.
//!
//!   cargo bench --bench hotpath            # full shapes
//!   MC_BENCH_FAST=1 cargo bench --bench hotpath   # CI smoke shapes
//!
//! Emits `BENCH_hotpath.json` (kernel + decode trajectory, consumed by
//! the CI bench-smoke artifact and EXPERIMENTS.md §Perf), keeps the
//! PR-1 `BENCH_dispatch.json` series going, and adds the expert
//! offload suite (`BENCH_offload.json`: tokens/s and miss-stall time
//! at 100%/60%/30% expert residency, EXPERIMENTS.md §Offload).
//!
//! The roofline-style kernel table (`BENCH_kernels.json`, modeled on
//! `python/compile/kernels/roofline.py`) times every hot kernel on
//! every compiled-and-runnable SIMD backend (`kernels::available()`)
//! and reports us, GB/s, GFLOP/s, and speedup vs the scalar
//! reference; CI bench-smoke asserts the AVX2 dequant-GEMM speedup.

use std::sync::Arc;
use std::time::Instant;

use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::coordinator::decode::{step_many_into, StepScratch};
use mc_moe::coordinator::{DecodeSession, Server};
use mc_moe::kernels;
use mc_moe::moe::exec::attention::{
    causal_attention_into, causal_attention_into_ops, AttnScratch,
};
use mc_moe::moe::exec::dispatch::{
    dispatch_experts, scatter, DispatchMode, ExpertsRef,
};
use mc_moe::moe::model::Expert;
use mc_moe::moe::{qz, MoeModel, WeightFile};
use mc_moe::offload::{self, PrefetchMode};
use mc_moe::quant::qmatmul::QmScratch;
use mc_moe::quant::{binary::binarize, linear::quantize_groupwise, qmatmul, QTensor};
use mc_moe::tensor::{matmul_into_naive, matmul_into_ops, matmul_into_with, Mat};
use mc_moe::util::bench::{bench_for, Table};
use mc_moe::util::pool::WorkerPool;
use mc_moe::util::rng::Rng;

// the one shared random-model fixture (also used by the integration
// tests) — no per-bench copy to drift out of sync
#[path = "../tests/common/mod.rs"]
mod common;
use common::random_model;

fn fast() -> bool {
    std::env::var("MC_BENCH_FAST").is_ok()
}

/// ms budget per timed kernel loop.
fn budget() -> u64 {
    if fast() { 60 } else { 800 }
}

fn threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------------
// Roofline-style kernel table: every compiled backend, per-kernel
// GB/s + GFLOP/s (modeled on python/compile/kernels/roofline.py)
// ---------------------------------------------------------------------------

struct KernelRow {
    kernel: String,
    backend: &'static str,
    us: f64,
    gb_s: f64,
    gflop_s: f64,
    /// vs the scalar table at the same shape (1.0 for scalar itself)
    speedup: f64,
}

fn record_kernel(
    rows: &mut Vec<KernelRow>,
    baseline_us: &mut std::collections::BTreeMap<String, f64>,
    kernel: &str,
    backend: &'static str,
    flops: f64,
    bytes: f64,
    us: f64,
) {
    // available() is scalar-first, so the first time a kernel name
    // appears it is the scalar measurement — that's the baseline
    let base = *baseline_us.entry(kernel.to_string()).or_insert(us);
    rows.push(KernelRow {
        kernel: kernel.to_string(),
        backend,
        us,
        gb_s: bytes / (us * 1e3),
        gflop_s: flops / (us * 1e3),
        speedup: base / us,
    });
}

/// Time every hot kernel on every backend the CPU can run. Bytes are
/// the per-call traffic of the kernel-facing buffers (weights +
/// activations + output read-modify-write); FLOP counts are the
/// mul-add work — both modeled, like the python roofline, so GB/s and
/// GFLOP/s are comparable across backends, not absolute truth.
fn kernels_suite() -> Vec<KernelRow> {
    let (k, n) = if fast() { (128usize, 128usize) } else { (256, 256) };
    let gemm_m = if fast() { 16usize } else { 64 };
    let big_m = 32usize;
    let (s, d, nh) = if fast() { (64usize, 64usize, 4usize) } else { (128, 128, 8) };
    let mut rng = Rng::new(20);
    let w = Mat::randn(&mut rng, k, n, 1.0);
    let q2 = quantize_groupwise(&w, 2);
    let q3 = quantize_groupwise(&w, 3);
    let q4 = quantize_groupwise(&w, 4);
    let b1 = binarize(&w, false);
    let xg = Mat::randn(&mut rng, gemm_m, k, 1.0);
    let x4 = Mat::randn(&mut rng, 4, k, 1.0);
    let xb = Mat::randn(&mut rng, big_m, k, 1.0);
    let aq = Mat::randn(&mut rng, s, d, 1.0);
    let ak = Mat::randn(&mut rng, s, d, 1.0);
    let av = Mat::randn(&mut rng, s, d, 1.0);

    let mut rows: Vec<KernelRow> = Vec::new();
    let mut baseline = std::collections::BTreeMap::new();
    for ops in kernels::available() {
        let backend = ops.isa.name();

        let mut y = Mat::zeros(gemm_m, n);
        let flops = 2.0 * (gemm_m * k * n) as f64;
        let bytes = 4.0 * (gemm_m * k + k * n + 2 * gemm_m * n) as f64;
        let r = bench_for("kern gemm_f32", budget() / 8, || {
            y.data.fill(0.0);
            matmul_into_ops(&xg, &w, &mut y, None, ops);
            std::hint::black_box(&y);
        });
        record_kernel(&mut rows, &mut baseline, "gemm_f32", backend, flops,
                      bytes, r.timings.mean_ns() / 1e3);

        // fused small-M dequant-GEMM at every packed bit-width
        // (decode shape: m = 4 <= small-M cutoff)
        for (name, q) in [("dequant2", &q2), ("dequant3", &q3),
                          ("dequant4", &q4)] {
            let mut y = Mat::zeros(0, 0);
            let mut qs = QmScratch::new();
            let flops = 2.0 * (4 * k * n) as f64;
            let bytes = 4.0 * (q.qweight.len() + q.scales.len()
                               + q.zeros.len() + 4 * k + 2 * 4 * n) as f64;
            let r = bench_for("kern dequant", budget() / 8, || {
                qmatmul::packed_matmul_into_ops(&x4, q, &mut y, &mut qs, ops);
                std::hint::black_box(&y);
            });
            record_kernel(&mut rows, &mut baseline, name, backend, flops,
                          bytes, r.timings.mean_ns() / 1e3);
        }

        // large-M path (dequant-row + dense axpy), 3-bit
        {
            let mut y = Mat::zeros(0, 0);
            let mut qs = QmScratch::new();
            let flops = 2.0 * (big_m * k * n) as f64;
            let bytes = 4.0 * (q3.qweight.len() + q3.scales.len()
                               + q3.zeros.len() + big_m * k
                               + 2 * big_m * n) as f64;
            let r = bench_for("kern dequant largeM", budget() / 8, || {
                qmatmul::packed_matmul_into_ops(&xb, &q3, &mut y, &mut qs, ops);
                std::hint::black_box(&y);
            });
            record_kernel(&mut rows, &mut baseline, "dequant3_largem",
                          backend, flops, bytes, r.timings.mean_ns() / 1e3);
        }

        {
            let mut y = Mat::zeros(0, 0);
            let mut qs = QmScratch::new();
            let flops = 2.0 * (4 * k * n) as f64;
            let bytes = 4.0 * (b1.packed.len() + b1.scales.len() + 4 * k
                               + 2 * 4 * n) as f64;
            let r = bench_for("kern binary", budget() / 8, || {
                qmatmul::binary_matmul_into_ops(&x4, &b1, &mut y, &mut qs, ops);
                std::hint::black_box(&y);
            });
            record_kernel(&mut rows, &mut baseline, "binary", backend, flops,
                          bytes, r.timings.mean_ns() / 1e3);
        }

        {
            let mut out = Mat::zeros(0, 0);
            let mut scratch = AttnScratch::new();
            // causal: ~s²·d mul-adds each for QK^T and AV
            let flops = 2.0 * (s * s * d) as f64;
            let bytes = 4.0 * (3 * s * d + 2 * s * s) as f64;
            let r = bench_for("kern attention", budget() / 8, || {
                causal_attention_into_ops(&aq, &ak, &av, s, nh, false, None,
                                          &mut scratch, &mut out, ops);
                std::hint::black_box(&out);
            });
            record_kernel(&mut rows, &mut baseline, "attention", backend,
                          flops, bytes, r.timings.mean_ns() / 1e3);
        }
    }

    let mut t = Table::new(
        &format!("hotpath — kernel roofline (k={k} n={n}; cpu: {})",
                 kernels::detected_summary()),
        &["kernel", "backend", "us", "GB/s", "GFLOP/s", "vs scalar"],
    );
    for r in &rows {
        t.row(vec![
            r.kernel.clone(),
            r.backend.to_string(),
            format!("{:.1}", r.us),
            format!("{:.2}", r.gb_s),
            format!("{:.2}", r.gflop_s),
            format!("{:.2}", r.speedup),
        ]);
    }
    t.print();
    rows
}

fn write_kernels_json(rows: &[KernelRow]) {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"kernel\": \"{}\", \"backend\": \"{}\", \
                 \"us\": {:.2}, \"gb_s\": {:.3}, \"gflop_s\": {:.3}, \
                 \"speedup_vs_scalar\": {:.3}}}",
                r.kernel, r.backend, r.us, r.gb_s, r.gflop_s, r.speedup,
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"fast\": {},\n  \"threads\": {},\n  \"cpu\": \"{}\",\n  \
         \"active_backend\": \"{}\",\n  \"kernels\": [\n{}\n  ]\n}}\n",
        fast(),
        threads(),
        kernels::detected_summary(),
        kernels::active().isa.name(),
        items.join(",\n"),
    );
    match std::fs::write("BENCH_kernels.json", &json) {
        Ok(()) => println!("wrote BENCH_kernels.json ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_kernels.json: {e}"),
    }
}

// ---------------------------------------------------------------------------
// GEMM: scalar-ikj baseline vs tiled vs pool strips
// ---------------------------------------------------------------------------

struct GemmResult {
    d: usize,
    naive_us: f64,
    tiled_us: f64,
    pool_us: f64,
    naive_m1_us: f64,
    tiled_m1_us: f64,
}

fn gemm_suite() -> GemmResult {
    let d = if fast() { 96 } else { 256 };
    let mut rng = Rng::new(0);
    let x = Mat::randn(&mut rng, d, d, 1.0);
    let w = Mat::randn(&mut rng, d, d, 1.0);
    let x1 = Mat::randn(&mut rng, 1, d, 1.0);
    let mut y = Mat::zeros(d, d);
    let mut y1 = Mat::zeros(1, d);
    let pool = WorkerPool::global();

    let r_naive = bench_for("gemm naive", budget(), || {
        y.data.fill(0.0);
        matmul_into_naive(&x, &w, &mut y);
        std::hint::black_box(&y);
    });
    let r_tiled = bench_for("gemm tiled", budget(), || {
        y.data.fill(0.0);
        matmul_into_with(&x, &w, &mut y, None);
        std::hint::black_box(&y);
    });
    let r_pool = bench_for("gemm pool", budget(), || {
        y.data.fill(0.0);
        matmul_into_with(&x, &w, &mut y, Some(pool));
        std::hint::black_box(&y);
    });
    let r_naive_m1 = bench_for("gemm naive M=1", budget() / 2, || {
        y1.data.fill(0.0);
        matmul_into_naive(&x1, &w, &mut y1);
        std::hint::black_box(&y1);
    });
    let r_tiled_m1 = bench_for("gemm tiled M=1", budget() / 2, || {
        y1.data.fill(0.0);
        matmul_into_with(&x1, &w, &mut y1, None);
        std::hint::black_box(&y1);
    });

    let res = GemmResult {
        d,
        naive_us: r_naive.timings.mean_ns() / 1e3,
        tiled_us: r_tiled.timings.mean_ns() / 1e3,
        pool_us: r_pool.timings.mean_ns() / 1e3,
        naive_m1_us: r_naive_m1.timings.mean_ns() / 1e3,
        tiled_m1_us: r_tiled_m1.timings.mean_ns() / 1e3,
    };
    let mut t = Table::new(
        &format!("hotpath — dense GEMM {d}x{d}x{d} (us, speedup vs scalar ikj)"),
        &["kernel", "us", "speedup"],
    );
    t.row(vec!["scalar ikj (naive)".into(), format!("{:.1}", res.naive_us),
               "1.00".into()]);
    t.row(vec!["tiled 4x4".into(), format!("{:.1}", res.tiled_us),
               format!("{:.2}", res.naive_us / res.tiled_us)]);
    t.row(vec![format!("tiled + pool (x{})", threads()),
               format!("{:.1}", res.pool_us),
               format!("{:.2}", res.naive_us / res.pool_us)]);
    t.row(vec!["M=1 scalar".into(), format!("{:.1}", res.naive_m1_us),
               "1.00".into()]);
    t.row(vec!["M=1 tiled".into(), format!("{:.1}", res.tiled_m1_us),
               format!("{:.2}", res.naive_m1_us / res.tiled_m1_us)]);
    t.print();
    res
}

// ---------------------------------------------------------------------------
// Packed matmul variants (decode shape M=1 uses the fused kernel)
// ---------------------------------------------------------------------------

fn matmul_variants_suite() {
    let mut t = Table::new(
        "hotpath — matmul variants (128x256 weight, M activation rows)",
        &["variant", "M=1 us", "M=16 us", "M=128 us", "GB read (w)"],
    );
    let mut rng = Rng::new(0);
    let k = 128usize;
    let n = 256usize;
    let w = Mat::randn(&mut rng, k, n, 1.0);
    let q2 = quantize_groupwise(&w, 2);
    let q3 = quantize_groupwise(&w, 3);
    let b1 = binarize(&w, false);
    for (name, f, bytes) in [
        (
            "dense f32",
            Box::new(|x: &Mat| x.matmul(&w)) as Box<dyn Fn(&Mat) -> Mat>,
            (k * n * 4) as f64,
        ),
        (
            "packed 2-bit",
            Box::new(|x: &Mat| qmatmul::packed_matmul(x, &q2)),
            (q2.qweight.len() * 4 + q2.scales.len() * 8) as f64,
        ),
        (
            "packed 3-bit",
            Box::new(|x: &Mat| qmatmul::packed_matmul(x, &q3)),
            (q3.qweight.len() * 4 + q3.scales.len() * 8) as f64,
        ),
        (
            "binary 1-bit",
            Box::new(|x: &Mat| qmatmul::binary_matmul(x, &b1)),
            (b1.packed.len() * 4 + b1.scales.len() * 4) as f64,
        ),
    ] {
        let mut cells = vec![name.to_string()];
        for m in [1usize, 16, 128] {
            let mut rng = Rng::new(m as u64);
            let x = Mat::randn(&mut rng, m, k, 1.0);
            let r = bench_for(name, budget() / 4, || {
                std::hint::black_box(f(&x));
            });
            cells.push(format!("{:.1}", r.timings.mean_ns() / 1e3));
        }
        cells.push(format!("{:.5}", bytes / 1e9));
        t.row(cells);
    }
    t.print();
}

// ---------------------------------------------------------------------------
// Attention: serial vs pooled head fan-out
// ---------------------------------------------------------------------------

struct AttnResult {
    s: usize,
    d: usize,
    heads: usize,
    serial_us: f64,
    pool_us: f64,
}

fn attention_suite() -> AttnResult {
    let (s, d, heads) = if fast() { (96, 96, 8) } else { (256, 256, 8) };
    let mut rng = Rng::new(2);
    let q = Mat::randn(&mut rng, s, d, 1.0);
    let k = Mat::randn(&mut rng, s, d, 1.0);
    let v = Mat::randn(&mut rng, s, d, 1.0);
    let mut scratch = AttnScratch::new();
    let mut out = Mat::zeros(0, 0);
    let pool = WorkerPool::global();
    let r_serial = bench_for("attention serial", budget(), || {
        causal_attention_into(&q, &k, &v, s, heads, false, None,
                              &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    let r_pool = bench_for("attention pool", budget(), || {
        causal_attention_into(&q, &k, &v, s, heads, false, Some(pool),
                              &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    let res = AttnResult {
        s,
        d,
        heads,
        serial_us: r_serial.timings.mean_ns() / 1e3,
        pool_us: r_pool.timings.mean_ns() / 1e3,
    };
    let mut t = Table::new(
        &format!("hotpath — attention S={s} d={d} heads={heads}"),
        &["mode", "us", "speedup"],
    );
    t.row(vec!["serial".into(), format!("{:.1}", res.serial_us), "1.00".into()]);
    t.row(vec![format!("pool (x{})", threads()),
               format!("{:.1}", res.pool_us),
               format!("{:.2}", res.serial_us / res.pool_us)]);
    t.print();
    res
}

// ---------------------------------------------------------------------------
// Expert dispatch: serial vs legacy spawns vs persistent pool
// ---------------------------------------------------------------------------

struct DispatchResult {
    serial_us: f64,
    spawn_us: f64,
    pool_us: f64,
}

fn dispatch_suite() -> DispatchResult {
    let (d, d_ff, n_experts, rows, top_k) = if fast() {
        (64usize, 256usize, 8usize, 64usize, 2usize)
    } else {
        (128, 512, 8, 128, 2)
    };
    let mut rng = Rng::new(7);
    let experts: Vec<Expert> = (0..n_experts)
        .map(|_| Expert {
            w1: QTensor::F32(Mat::randn(&mut rng, d, d_ff, 0.05)),
            w3: QTensor::F32(Mat::randn(&mut rng, d, d_ff, 0.05)),
            w2: QTensor::F32(Mat::randn(&mut rng, d_ff, d, 0.05)),
        })
        .collect();
    let h = Mat::randn(&mut rng, rows, d, 1.0);
    // balanced round-robin routing so every expert carries work
    let topk: Vec<Vec<(usize, f32)>> = (0..rows)
        .map(|t| {
            (0..top_k)
                .map(|j| ((t + j) % n_experts, 1.0 / top_k as f32))
                .collect()
        })
        .collect();

    let run = |mode: DispatchMode| {
        bench_for("dispatch", budget(), || {
            let b = dispatch_experts(&h, &topk, ExpertsRef::resident(&experts),
                                     None, mode);
            std::hint::black_box(scatter(&b, rows, d));
        })
        .timings
        .mean_ns()
            / 1e3
    };
    let serial_us = run(DispatchMode::Serial);
    let spawn_us = run(DispatchMode::SpawnScope);
    let pool_us = run(DispatchMode::Threaded);

    let mut t = Table::new(
        "hotpath — expert dispatch (serial vs spawn-per-call vs pool)",
        &["mode", "us/layer", "speedup vs serial"],
    );
    t.row(vec!["serial".into(), format!("{serial_us:.1}"), "1.00".into()]);
    t.row(vec!["thread::scope spawns".into(), format!("{spawn_us:.1}"),
               format!("{:.2}", serial_us / spawn_us)]);
    t.row(vec![format!("pool (x{})", threads()), format!("{pool_us:.1}"),
               format!("{:.2}", serial_us / pool_us)]);
    t.print();

    // keep the PR-1 BENCH_dispatch.json series alive (threaded == pool)
    let speedup = serial_us / pool_us;
    let json = format!(
        "{{\n  \"shape\": {{\"d_model\": {d}, \"d_ff\": {d_ff}, \
         \"n_experts\": {n_experts}, \"rows\": {rows}, \"top_k\": {top_k}}},\n  \
         \"threads\": {},\n  \
         \"serial_us\": {serial_us:.1},\n  \
         \"spawn_us\": {spawn_us:.1},\n  \
         \"threaded_us\": {pool_us:.1},\n  \
         \"speedup\": {speedup:.3}\n}}\n",
        threads(),
    );
    match std::fs::write("BENCH_dispatch.json", &json) {
        Ok(()) => println!("wrote BENCH_dispatch.json (pool speedup {speedup:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_dispatch.json: {e}"),
    }
    DispatchResult { serial_us, spawn_us, pool_us }
}

// ---------------------------------------------------------------------------
// End-to-end fused multi-session decode: tokens/s per dispatch mode
// ---------------------------------------------------------------------------

struct DecodeResult {
    cfg: ModelConfig,
    batch: usize,
    steps: usize,
    serial_tok_s: f64,
    spawn_tok_s: f64,
    pool_tok_s: f64,
}

fn decode_suite() -> DecodeResult {
    let cfg = if fast() {
        ModelConfig {
            name: "bench-fast".into(),
            vocab_size: 256,
            d_model: 48,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            n_experts: 8,
            top_k: 2,
            max_seq: 64,
            prefill_tile: 32,
        }
    } else {
        ModelConfig {
            name: "bench".into(),
            vocab_size: 256,
            d_model: 96,
            n_layers: 4,
            n_heads: 4,
            d_ff: 384,
            n_experts: 8,
            top_k: 2,
            max_seq: 192,
            prefill_tile: 64,
        }
    };
    let model = Arc::new(random_model(&cfg, 11));
    let batch = 8usize;
    let prompt_len = 16usize.min(cfg.max_seq / 4);
    let steps = if fast() { 8 } else { 48.min(cfg.max_seq - prompt_len - 1) };

    let run_mode = |mode: DispatchMode| -> f64 {
        let mut sessions: Vec<DecodeSession> = (0..batch)
            .map(|i| {
                let mut s = DecodeSession::new(model.clone(), None);
                let prompt: Vec<u32> =
                    (0..prompt_len).map(|t| ((t * 7 + i) % 200 + 1) as u32).collect();
                s.prefill(&prompt);
                s
            })
            .collect();
        let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
        let toks: Vec<u32> = (0..batch).map(|i| (i % 200 + 1) as u32).collect();
        let mut sc = StepScratch::new();
        sc.dispatch_mode = mode;
        // warmup (grow scratch, start pool)
        step_many_into(&mut refs, &toks, &mut sc);
        let t0 = Instant::now();
        for _ in 0..steps {
            std::hint::black_box(step_many_into(&mut refs, &toks, &mut sc));
        }
        (batch * steps) as f64 / t0.elapsed().as_secs_f64()
    };

    let serial_tok_s = run_mode(DispatchMode::Serial);
    let spawn_tok_s = run_mode(DispatchMode::SpawnScope);
    let pool_tok_s = run_mode(DispatchMode::Threaded);

    let mut t = Table::new(
        &format!(
            "hotpath — fused decode tokens/s (b={batch}, {} layers, d={})",
            cfg.n_layers, cfg.d_model
        ),
        &["expert execution", "tok/s", "vs spawns"],
    );
    t.row(vec!["serial".into(), format!("{serial_tok_s:.0}"),
               format!("{:.2}", serial_tok_s / spawn_tok_s)]);
    t.row(vec!["spawn-per-step (legacy)".into(), format!("{spawn_tok_s:.0}"),
               "1.00".into()]);
    t.row(vec![format!("worker pool (x{})", threads()),
               format!("{pool_tok_s:.0}"),
               format!("{:.2}", pool_tok_s / spawn_tok_s)]);
    t.print();
    DecodeResult { cfg, batch, steps, serial_tok_s, spawn_tok_s, pool_tok_s }
}

// ---------------------------------------------------------------------------
// Expert offload: fused-decode tokens/s + stall time vs residency budget
// ---------------------------------------------------------------------------

struct OffloadRow {
    residency: f64,
    budget_mb: f64,
    tok_s: f64,
    hits: u64,
    misses: u64,
    prefetch_issued: u64,
    prefetch_hits: u64,
    evictions: u64,
    stall_ms_mean: f64,
    bytes_resident: u64,
}

/// Budget sweep over the decode-suite model: 100% residency (cache
/// covers every expert) vs 60% and 30%, fused multi-session decode.
fn offload_suite() -> Vec<OffloadRow> {
    let cfg = if fast() {
        ModelConfig {
            name: "bench-fast".into(),
            vocab_size: 256,
            d_model: 48,
            n_layers: 2,
            n_heads: 4,
            d_ff: 192,
            n_experts: 8,
            top_k: 2,
            max_seq: 64,
            prefill_tile: 32,
        }
    } else {
        ModelConfig {
            name: "bench".into(),
            vocab_size: 256,
            d_model: 96,
            n_layers: 4,
            n_heads: 4,
            d_ff: 384,
            n_experts: 8,
            top_k: 2,
            max_seq: 192,
            prefill_tile: 64,
        }
    };
    let source = random_model(&cfg, 11);
    let expert_bytes: usize = source.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();
    let path = std::env::temp_dir()
        .join(format!("mc_bench_offload_{}.mcqz", std::process::id()));
    qz::save(&path, &source).unwrap();
    drop(source);

    let batch = 4usize;
    let prompt_len = 16usize.min(cfg.max_seq / 4);
    let steps = if fast() { 8 } else { 48.min(cfg.max_seq - prompt_len - 1) };

    let mut rows = Vec::new();
    for residency in [1.0f64, 0.6, 0.3] {
        let budget = (expert_bytes as f64 * residency).ceil() as usize;
        let model = Arc::new(
            offload::load_cached(&path, budget, PrefetchMode::Async).unwrap());
        let metrics = model.resolver.metrics().unwrap();
        let mut sessions: Vec<DecodeSession> = (0..batch)
            .map(|i| {
                let mut s = DecodeSession::new(model.clone(), None);
                let prompt: Vec<u32> = (0..prompt_len)
                    .map(|t| ((t * 7 + i) % 200 + 1) as u32)
                    .collect();
                s.prefill(&prompt);
                s
            })
            .collect();
        let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
        let toks: Vec<u32> = (0..batch).map(|i| (i % 200 + 1) as u32).collect();
        let mut sc = StepScratch::new();
        // warmup (grow scratch, spin up cache + prefetcher)
        step_many_into(&mut refs, &toks, &mut sc);
        let t0 = Instant::now();
        for _ in 0..steps {
            std::hint::black_box(step_many_into(&mut refs, &toks, &mut sc));
        }
        let tok_s = (batch * steps) as f64 / t0.elapsed().as_secs_f64();
        use std::sync::atomic::Ordering::Relaxed;
        rows.push(OffloadRow {
            residency,
            budget_mb: budget as f64 / (1 << 20) as f64,
            tok_s,
            hits: metrics.expert_cache_hits.load(Relaxed),
            misses: metrics.expert_cache_misses.load(Relaxed),
            prefetch_issued: metrics.expert_prefetch_issued.load(Relaxed),
            prefetch_hits: metrics.expert_prefetch_hits.load(Relaxed),
            evictions: metrics.expert_cache_evictions.load(Relaxed),
            stall_ms_mean: metrics.miss_stall_ns.lock().unwrap().mean() / 1e6,
            bytes_resident: metrics.bytes_resident.load(Relaxed),
        });
    }
    std::fs::remove_file(&path).ok();

    let mut t = Table::new(
        &format!(
            "hotpath — offload fused decode (b={batch}, {} layers, \
             {:.2} MB experts)",
            cfg.n_layers, expert_bytes as f64 / 1e6
        ),
        &["residency", "tok/s", "hit/miss", "prefetch", "evict",
          "stall ms"],
    );
    for r in &rows {
        t.row(vec![
            format!("{:.0}%", r.residency * 100.0),
            format!("{:.0}", r.tok_s),
            format!("{}/{}", r.hits, r.misses),
            format!("{}/{}", r.prefetch_hits, r.prefetch_issued),
            format!("{}", r.evictions),
            format!("{:.3}", r.stall_ms_mean),
        ]);
    }
    t.print();
    rows
}

fn write_offload_json(rows: &[OffloadRow]) {
    let budgets: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"residency\": {:.2}, \"budget_mb\": {:.3}, \
                 \"tok_s\": {:.1}, \"hits\": {}, \"misses\": {}, \
                 \"prefetch_issued\": {}, \"prefetch_hits\": {}, \
                 \"evictions\": {}, \"stall_ms_mean\": {:.4}, \
                 \"bytes_resident\": {}}}",
                r.residency, r.budget_mb, r.tok_s, r.hits, r.misses,
                r.prefetch_issued, r.prefetch_hits, r.evictions,
                r.stall_ms_mean, r.bytes_resident,
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"fast\": {},\n  \"threads\": {},\n  \"budgets\": [\n{}\n  ]\n}}\n",
        fast(),
        threads(),
        budgets.join(",\n"),
    );
    match std::fs::write("BENCH_offload.json", &json) {
        Ok(()) => println!("wrote BENCH_offload.json"),
        Err(e) => eprintln!("could not write BENCH_offload.json: {e}"),
    }
}

// ---------------------------------------------------------------------------
// Engine paths (artifact-gated)
// ---------------------------------------------------------------------------

fn engine_suite() {
    let dir = artifacts_dir();
    let Ok(cfg) = ModelConfig::load(&dir.join("config.json")) else {
        eprintln!("skipping engine suite: artifacts not built");
        return;
    };
    let wf = WeightFile::load(&dir.join("weights.mcwt")).unwrap();
    let fp = Arc::new(MoeModel::load_f32(&cfg, wf).unwrap());

    let mut t = Table::new("hotpath — engine paths", &["path", "ms/unit", "unit"]);

    // full-seq native scoring
    let toks: Vec<u32> = (0..cfg.max_seq as u32).map(|i| i % 200 + 1).collect();
    let r = bench_for("native score", budget(), || {
        std::hint::black_box(fp.score(&toks));
    });
    t.row(vec!["native full-seq score".into(),
               format!("{:.2}", r.mean_ms()), format!("seq{}", cfg.max_seq)]);

    // single-shot batched prefill (fills the KV cache in one pass);
    // session allocated once and rewound so only prefill is timed
    let mut psess = DecodeSession::new(fp.clone(), None);
    let r = bench_for("batched prefill", budget(), || {
        psess.reset();
        std::hint::black_box(psess.prefill(&toks[..64]));
    });
    t.row(vec!["batched prefill (KV)".into(), format!("{:.3}", r.mean_ms()),
               "64 tok".into()]);

    // decode step (zero-alloc into-path with a reused logits buffer)
    let mut sess = DecodeSession::new(fp.clone(), None);
    sess.prefill(&toks[..64]);
    let mut logits = Vec::new();
    let mut i = 0u32;
    let r = bench_for("decode step", budget(), || {
        if sess.remaining() == 0 {
            sess = DecodeSession::new(fp.clone(), None);
            sess.prefill(&toks[..64]);
        }
        i += 1;
        sess.step_into(i % 200 + 1, &mut logits);
        std::hint::black_box(&logits);
    });
    t.row(vec!["decode step (KV)".into(), format!("{:.3}", r.mean_ms()),
               "token".into()]);

    // PJRT full-forward (stub PjrtModel errors when the feature is off,
    // so the cfg! guard keeps this branch dead there)
    if cfg!(feature = "pjrt") && dir.join("model_fwd.hlo.txt").exists() {
        let mut pm = mc_moe::runtime::PjrtModel::load(&dir).unwrap();
        let r = bench_for("pjrt score", 2000, || {
            std::hint::black_box(pm.score(&toks).unwrap());
        });
        t.row(vec!["PJRT model_fwd score".into(), format!("{:.2}", r.mean_ms()),
                   format!("seq{}", cfg.max_seq)]);
    }

    // batched serving throughput (fused multi-session decode)
    let t0 = Instant::now();
    let server = Server::spawn(fp.clone(), None, 4);
    let mut rng = Rng::new(3);
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let prompt: Vec<u32> = (0..32).map(|_| rng.below(200) as u32 + 1).collect();
            server.submit_greedy(prompt, 16)
        })
        .collect();
    for h in handles {
        let _ = h.wait();
    }
    let tokens = server.metrics.tokens_generated
        .load(std::sync::atomic::Ordering::Relaxed) as f64;
    t.row(vec!["batched serving".into(),
               format!("{:.1}", tokens / t0.elapsed().as_secs_f64()),
               "tok/s (b=4)".into()]);
    server.shutdown();
    t.print();
}

// ---------------------------------------------------------------------------

fn write_hotpath_json(gemm: &GemmResult, attn: &AttnResult,
                      disp: &DispatchResult, dec: &DecodeResult) {
    let json = format!(
        "{{\n  \"fast\": {},\n  \"threads\": {},\n  \
         \"kernel_backend\": \"{}\",\n  \
         \"gemm\": {{\"d\": {}, \"naive_us\": {:.1}, \"tiled_us\": {:.1}, \
         \"pool_us\": {:.1}, \"tiled_speedup\": {:.3}, \"pool_speedup\": {:.3}, \
         \"naive_m1_us\": {:.2}, \"tiled_m1_us\": {:.2}}},\n  \
         \"attention\": {{\"s\": {}, \"d\": {}, \"heads\": {}, \
         \"serial_us\": {:.1}, \"pool_us\": {:.1}, \"speedup\": {:.3}}},\n  \
         \"dispatch\": {{\"serial_us\": {:.1}, \"spawn_us\": {:.1}, \
         \"pool_us\": {:.1}, \"pool_vs_spawn\": {:.3}}},\n  \
         \"decode\": {{\"batch\": {}, \"layers\": {}, \"d_model\": {}, \
         \"d_ff\": {}, \"n_experts\": {}, \"steps\": {}, \
         \"serial_tok_s\": {:.1}, \"spawn_tok_s\": {:.1}, \
         \"pool_tok_s\": {:.1}, \"pool_vs_spawn\": {:.3}, \
         \"pool_vs_serial\": {:.3}}}\n}}\n",
        fast(),
        threads(),
        kernels::active().isa.name(),
        gemm.d,
        gemm.naive_us,
        gemm.tiled_us,
        gemm.pool_us,
        gemm.naive_us / gemm.tiled_us,
        gemm.naive_us / gemm.pool_us,
        gemm.naive_m1_us,
        gemm.tiled_m1_us,
        attn.s,
        attn.d,
        attn.heads,
        attn.serial_us,
        attn.pool_us,
        attn.serial_us / attn.pool_us,
        disp.serial_us,
        disp.spawn_us,
        disp.pool_us,
        disp.spawn_us / disp.pool_us,
        dec.batch,
        dec.cfg.n_layers,
        dec.cfg.d_model,
        dec.cfg.d_ff,
        dec.cfg.n_experts,
        dec.steps,
        dec.serial_tok_s,
        dec.spawn_tok_s,
        dec.pool_tok_s,
        dec.pool_tok_s / dec.spawn_tok_s,
        dec.pool_tok_s / dec.serial_tok_s,
    );
    match std::fs::write("BENCH_hotpath.json", &json) {
        Ok(()) => println!("wrote BENCH_hotpath.json"),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}

fn main() {
    kernels::log_selection();
    let kern = kernels_suite();
    write_kernels_json(&kern);
    let gemm = gemm_suite();
    matmul_variants_suite();
    let attn = attention_suite();
    let disp = dispatch_suite();
    let dec = decode_suite();
    write_hotpath_json(&gemm, &attn, &disp, &dec);
    let off = offload_suite();
    write_offload_json(&off);
    if !fast() {
        engine_suite();
    }
}
