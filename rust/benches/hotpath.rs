//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): packed dequant
//! matmul vs dense f32, binary matmul, decode step latency, serial vs
//! threaded expert dispatch (emits BENCH_dispatch.json), PJRT
//! full-forward vs native (with the `pjrt` feature), and batcher
//! throughput.
//!
//!   cargo bench --bench hotpath

use std::sync::Arc;
use std::time::Instant;

use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::coordinator::{DecodeSession, Server};
use mc_moe::moe::exec::dispatch::{dispatch_experts, scatter, DispatchMode};
use mc_moe::moe::model::Expert;
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::quant::{binary::binarize, linear::quantize_groupwise, qmatmul, QTensor};
use mc_moe::tensor::Mat;
use mc_moe::util::bench::{bench_for, Table};
use mc_moe::util::rng::Rng;

fn matmul_suite() {
    let mut t = Table::new(
        "hotpath — matmul variants (128x256 weight, M activation rows)",
        &["variant", "M=1 us", "M=16 us", "M=128 us", "GB read (w)"],
    );
    let mut rng = Rng::new(0);
    let k = 128usize;
    let n = 256usize;
    let w = Mat::randn(&mut rng, k, n, 1.0);
    let q2 = quantize_groupwise(&w, 2);
    let q3 = quantize_groupwise(&w, 3);
    let b1 = binarize(&w, false);
    for (name, f, bytes) in [
        (
            "dense f32",
            Box::new(|x: &Mat| x.matmul(&w)) as Box<dyn Fn(&Mat) -> Mat>,
            (k * n * 4) as f64,
        ),
        (
            "packed 2-bit",
            Box::new(|x: &Mat| qmatmul::packed_matmul(x, &q2)),
            (q2.qweight.len() * 4 + q2.scales.len() * 8) as f64,
        ),
        (
            "packed 3-bit",
            Box::new(|x: &Mat| qmatmul::packed_matmul(x, &q3)),
            (q3.qweight.len() * 4 + q3.scales.len() * 8) as f64,
        ),
        (
            "binary 1-bit",
            Box::new(|x: &Mat| qmatmul::binary_matmul(x, &b1)),
            (b1.packed.len() * 4 + b1.scales.len() * 4) as f64,
        ),
    ] {
        let mut cells = vec![name.to_string()];
        for m in [1usize, 16, 128] {
            let mut rng = Rng::new(m as u64);
            let x = Mat::randn(&mut rng, m, k, 1.0);
            let r = bench_for(name, 200, || {
                std::hint::black_box(f(&x));
            });
            cells.push(format!("{:.1}", r.timings.mean_ns() / 1e3));
        }
        cells.push(format!("{:.5}", bytes / 1e9));
        t.row(cells);
    }
    t.print();
}

/// Serial vs `std::thread::scope`-threaded expert dispatch at a
/// serving-representative shape; records the comparison in
/// BENCH_dispatch.json (ISSUE 1 acceptance: threaded >= 1.5x serial).
fn dispatch_suite() {
    let (d, d_ff, n_experts, rows, top_k) = (128usize, 512usize, 8usize, 128usize, 2usize);
    let mut rng = Rng::new(7);
    let experts: Vec<Expert> = (0..n_experts)
        .map(|_| Expert {
            w1: QTensor::F32(Mat::randn(&mut rng, d, d_ff, 0.05)),
            w3: QTensor::F32(Mat::randn(&mut rng, d, d_ff, 0.05)),
            w2: QTensor::F32(Mat::randn(&mut rng, d_ff, d, 0.05)),
        })
        .collect();
    let h = Mat::randn(&mut rng, rows, d, 1.0);
    // balanced round-robin routing so every expert carries work
    let topk: Vec<Vec<(usize, f32)>> = (0..rows)
        .map(|t| {
            (0..top_k)
                .map(|j| ((t + j) % n_experts, 1.0 / top_k as f32))
                .collect()
        })
        .collect();

    let r_serial = bench_for("dispatch serial", 1500, || {
        let b = dispatch_experts(&h, &topk, &experts, None, DispatchMode::Serial);
        std::hint::black_box(scatter(&b, rows, d));
    });
    let r_threaded = bench_for("dispatch threaded", 1500, || {
        let b = dispatch_experts(&h, &topk, &experts, None, DispatchMode::Threaded);
        std::hint::black_box(scatter(&b, rows, d));
    });
    let serial_us = r_serial.timings.mean_ns() / 1e3;
    let threaded_us = r_threaded.timings.mean_ns() / 1e3;
    let speedup = serial_us / threaded_us;
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);

    let mut t = Table::new(
        "hotpath — expert dispatch (serial vs thread::scope)",
        &["mode", "us/layer", "speedup"],
    );
    t.row(vec!["serial".into(), format!("{serial_us:.1}"), "1.00".into()]);
    t.row(vec![
        format!("threaded (x{threads})"),
        format!("{threaded_us:.1}"),
        format!("{speedup:.2}"),
    ]);
    t.print();

    let json = format!(
        "{{\n  \"shape\": {{\"d_model\": {d}, \"d_ff\": {d_ff}, \
         \"n_experts\": {n_experts}, \"rows\": {rows}, \"top_k\": {top_k}}},\n  \
         \"threads\": {threads},\n  \
         \"serial_us\": {serial_us:.1},\n  \
         \"threaded_us\": {threaded_us:.1},\n  \
         \"speedup\": {speedup:.3}\n}}\n"
    );
    match std::fs::write("BENCH_dispatch.json", &json) {
        Ok(()) => println!("wrote BENCH_dispatch.json (speedup {speedup:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_dispatch.json: {e}"),
    }
}

fn engine_suite() {
    let dir = artifacts_dir();
    let Ok(cfg) = ModelConfig::load(&dir.join("config.json")) else {
        eprintln!("skipping engine suite: artifacts not built");
        return;
    };
    let wf = WeightFile::load(&dir.join("weights.mcwt")).unwrap();
    let fp = Arc::new(MoeModel::load_f32(&cfg, &wf).unwrap());

    let mut t = Table::new("hotpath — engine paths", &["path", "ms/unit", "unit"]);

    // full-seq native scoring
    let toks: Vec<u32> = (0..cfg.max_seq as u32).map(|i| i % 200 + 1).collect();
    let r = bench_for("native score", 1500, || {
        std::hint::black_box(fp.score(&toks));
    });
    t.row(vec!["native full-seq score".into(),
               format!("{:.2}", r.mean_ms()), format!("seq{}", cfg.max_seq)]);

    // single-shot batched prefill (fills the KV cache in one pass);
    // session allocated once and rewound so only prefill is timed
    let mut psess = DecodeSession::new(fp.clone(), None);
    let r = bench_for("batched prefill", 1000, || {
        psess.reset();
        std::hint::black_box(psess.prefill(&toks[..64]));
    });
    t.row(vec!["batched prefill (KV)".into(), format!("{:.3}", r.mean_ms()),
               "64 tok".into()]);

    // decode step
    let mut sess = DecodeSession::new(fp.clone(), None);
    sess.prefill(&toks[..64]);
    let mut i = 0u32;
    let r = bench_for("decode step", 1000, || {
        if sess.remaining() == 0 {
            sess = DecodeSession::new(fp.clone(), None);
            sess.prefill(&toks[..64]);
        }
        i += 1;
        std::hint::black_box(sess.step(i % 200 + 1));
    });
    t.row(vec!["decode step (KV)".into(), format!("{:.3}", r.mean_ms()),
               "token".into()]);

    // PJRT full-forward (stub PjrtModel errors when the feature is off,
    // so the cfg! guard keeps this branch dead there)
    if cfg!(feature = "pjrt") && dir.join("model_fwd.hlo.txt").exists() {
        let mut pm = mc_moe::runtime::PjrtModel::load(&dir).unwrap();
        let r = bench_for("pjrt score", 2000, || {
            std::hint::black_box(pm.score(&toks).unwrap());
        });
        t.row(vec!["PJRT model_fwd score".into(), format!("{:.2}", r.mean_ms()),
                   format!("seq{}", cfg.max_seq)]);
    }

    // batched serving throughput (fused multi-session decode)
    let t0 = Instant::now();
    let server = Server::spawn(fp.clone(), None, 4);
    let mut rng = Rng::new(3);
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let prompt: Vec<u32> = (0..32).map(|_| rng.below(200) as u32 + 1).collect();
            server.submit_greedy(prompt, 16)
        })
        .collect();
    for h in handles {
        let _ = h.wait();
    }
    let tokens = server.metrics.tokens_generated
        .load(std::sync::atomic::Ordering::Relaxed) as f64;
    t.row(vec!["batched serving".into(),
               format!("{:.1}", tokens / t0.elapsed().as_secs_f64()),
               "tok/s (b=4)".into()]);
    server.shutdown();
    t.print();
}

fn main() {
    matmul_suite();
    dispatch_suite();
    engine_suite();
}
