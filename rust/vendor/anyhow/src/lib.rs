//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so the workspace vendors
//! the small subset of anyhow it actually uses: a string-backed
//! `Error`, the `Result` alias, the `anyhow!` / `bail!` / `ensure!`
//! macros, and the `Context` extension trait for `Result` and
//! `Option`. Error payloads are rendered eagerly into the message —
//! good enough for a CLI/serving stack that only ever prints them.

use std::fmt;

/// String-backed error. Deliberately does NOT implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// below cannot collide with the identity `From<Error>`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, rendered as `"{context}: {cause}"`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading x").unwrap_err();
        assert_eq!(e.to_string(), "reading x: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format() {
        fn inner(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through at {}", n))
        }
        assert!(inner(11).unwrap_err().to_string().contains("11"));
        assert!(inner(5).unwrap_err().to_string().contains("right out"));
        assert!(inner(1).unwrap_err().to_string().contains("at 1"));
    }
}
