//! Path-equivalence tests over the shared execution core (ISSUE 1):
//! the scoring forward, single-shot batched prefill, token-by-token
//! KV decode, and the fused multi-session batcher step must all agree
//! — logits AND pruning decisions — with ODP on and off.

use std::sync::Arc;

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::{DecodeOdp, DecodeSession};
use mc_moe::moe::model::{CalibSink, ForwardOpts, OdpPolicy};
use mc_moe::tensor::Mat;
use mc_moe::util::stats::argmax;

mod common;
use common::random_model;

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

/// Batched prefill must reproduce the full-sequence scorer's last-row
/// logits (the cross-path analogue of `decode_matches_full_forward`).
#[test]
fn batched_prefill_matches_scoring_forward() {
    let cfg = ModelConfig::test_tiny();
    let model = Arc::new(random_model(&cfg, 10));
    let toks: Vec<u32> = (0..30).map(|i| (i * 13) % 200 + 1).collect();
    let full = model.score(&toks);
    let mut sess = DecodeSession::new(model.clone(), None);
    let got = sess.prefill(&toks);
    close(&got, full.row(toks.len() - 1), 1e-3, "prefill vs score");
    assert_eq!(sess.pos, toks.len());
}

/// Batched prefill + fused multi-session stepping must reproduce
/// token-by-token decode, ODP off and on.
#[test]
fn fused_pipeline_matches_stepwise_decode() {
    let cfg = ModelConfig::test_tiny();
    let model = Arc::new(random_model(&cfg, 11));
    let prompts: [&[u32]; 3] = [&[1, 5, 80, 3], &[2, 44, 9], &[7, 7, 120, 33, 14]];
    let n_decode = 4;
    for odp in [
        None,
        Some(DecodeOdp { mu: vec![0.6; cfg.n_layers], l1_threshold: None }),
    ] {
        // reference: sequential step() per token, per session
        let mut want_tokens: Vec<Vec<u32>> = Vec::new();
        let mut want_logits: Vec<Vec<f32>> = Vec::new();
        let mut want_pruned = 0usize;
        for p in &prompts {
            let mut s = DecodeSession::new(model.clone(), odp.clone());
            let mut logits = Vec::new();
            for &t in *p {
                logits = s.step(t);
            }
            let mut toks = Vec::new();
            for _ in 0..n_decode {
                let next = argmax(&logits) as u32;
                toks.push(next);
                logits = s.step(next);
            }
            want_tokens.push(toks);
            want_logits.push(logits);
            want_pruned += s.stats.dropped_secondary;
        }

        // fused: batched prefill, then step_many across all sessions
        let mut sessions: Vec<DecodeSession> = prompts
            .iter()
            .map(|p| {
                let mut s = DecodeSession::new(model.clone(), odp.clone());
                s.prefill(&p[..p.len() - 1]);
                s
            })
            .collect();
        let mut inputs: Vec<u32> = prompts.iter().map(|p| *p.last().unwrap()).collect();
        let mut got_tokens: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
        let mut logits = Vec::new();
        for _ in 0..=n_decode {
            logits = {
                let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                mc_moe::coordinator::decode::step_many(&mut refs, &inputs)
            };
            inputs = (0..prompts.len())
                .map(|i| {
                    let next = argmax(&logits[i]) as u32;
                    got_tokens[i].push(next);
                    next
                })
                .collect();
        }
        for i in 0..prompts.len() {
            // the last greedy pick follows the final compared logits;
            // compare the first n_decode tokens and the final logits
            assert_eq!(&got_tokens[i][..n_decode], &want_tokens[i][..],
                       "session {i} token stream diverged (odp={})",
                       odp.is_some());
            close(&logits[i], &want_logits[i], 1e-4,
                  &format!("session {i} final logits"));
        }
        let got_pruned: usize =
            sessions.iter().map(|s| s.stats.dropped_secondary).sum();
        assert_eq!(got_pruned, want_pruned, "pruning drift (odp={})",
                   odp.is_some());
    }
}

/// `OdpPolicy::WeightOnly` scoring and `DecodeOdp` decode implement
/// the same w1/w0 rule: on the same sequence they must prune the same
/// per-token counts (hence the same token sets) and agree on totals.
#[test]
fn weight_only_scoring_and_decode_prune_same_tokens() {
    let cfg = ModelConfig::test_tiny();
    let model = Arc::new(random_model(&cfg, 12));
    let toks: Vec<u32> = (0..32).map(|i| (i * 29) % 200 + 1).collect();
    let mu = vec![0.6f32; cfg.n_layers];

    // scoring path: per-token prune count via the routing sink
    struct PruneSink {
        per_token: Vec<usize>,
    }
    impl CalibSink for PruneSink {
        fn routing(&mut self, _layer: usize, _probs: &Mat,
                   topk: &[Vec<(usize, f32)>]) {
            if self.per_token.is_empty() {
                self.per_token = vec![0; topk.len()];
            }
            for (t, sel) in topk.iter().enumerate() {
                if sel.len() < 2 {
                    self.per_token[t] += 1;
                }
            }
        }
    }
    let policy = OdpPolicy::WeightOnly { mu: mu.clone() };
    let mut sink = PruneSink { per_token: Vec::new() };
    let opts = ForwardOpts { odp: Some(&policy), ..Default::default() };
    let score_out = model.forward(&toks, &opts, &mut sink);
    let score_per_token = sink.per_token;

    // decode path: per-token prune count via stepwise stat deltas
    let odp = DecodeOdp { mu, l1_threshold: None };
    let mut sess = DecodeSession::new(model.clone(), Some(odp));
    let mut decode_per_token = Vec::new();
    let mut last = 0usize;
    for &t in &toks {
        sess.step(t);
        decode_per_token.push(sess.stats.dropped_secondary - last);
        last = sess.stats.dropped_secondary;
    }

    assert_eq!(score_per_token, decode_per_token,
               "scoring and decode pruned different token sets");
    assert_eq!(score_out.stats.dropped_secondary,
               sess.stats.dropped_secondary);
    assert_eq!(score_out.stats.expert_calls, sess.stats.expert_calls);
    assert_eq!(score_out.stats.expert_possible, sess.stats.expert_possible);
    // and some pruning actually happened at the median-ish threshold
    assert!(sess.stats.dropped_secondary > 0);
}
