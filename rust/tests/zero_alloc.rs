//! Zero-allocation decode hot path (ISSUE 4 acceptance): after warmup,
//! steady-state `step_many_into` must perform **no heap allocation**
//! in the attention / dispatch / GEMM paths — asserted with a counting
//! global allocator, plus buffer-pointer-stability checks on the
//! scratch arenas.
//!
//! This file is its own test binary so the `#[global_allocator]` hook
//! cannot interfere with other suites; it holds a single #[test] so no
//! concurrent test thread pollutes the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::decode::{step_many_into, DecodeSession, StepScratch};

mod common;
use common::random_model;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_fused_decode_allocates_nothing() {
    let cfg = ModelConfig::test_tiny();
    let model = Arc::new(random_model(&cfg, 42));
    let mut sessions: Vec<DecodeSession> = (0..3)
        .map(|i| {
            let mut s = DecodeSession::new(model.clone(), None);
            s.prefill(&[1, 5 + i as u32, 80, 3]);
            s
        })
        .collect();
    let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
    let toks = [10u32, 11, 12];
    let mut sc = StepScratch::new();

    // warmup: grow every scratch buffer to its steady-state shape
    // (and start the worker pool, if this host engages it)
    for _ in 0..4 {
        step_many_into(&mut refs, &toks, &mut sc);
    }
    let probe = [
        sc.x.data.as_ptr(),
        sc.h.data.as_ptr(),
        sc.q.data.as_ptr(),
        sc.probs.data.as_ptr(),
        sc.moe_y.data.as_ptr(),
        sc.logits.data.as_ptr(),
    ];

    // measured steady state: zero heap allocations across attention,
    // routing, dispatch, and every GEMM
    let before = allocs();
    for _ in 0..16 {
        step_many_into(&mut refs, &toks, &mut sc);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "steady-state step_many_into allocated {delta} times in 16 steps"
    );
    assert_eq!(
        probe,
        [
            sc.x.data.as_ptr(),
            sc.h.data.as_ptr(),
            sc.q.data.as_ptr(),
            sc.probs.data.as_ptr(),
            sc.moe_y.data.as_ptr(),
            sc.logits.data.as_ptr(),
        ],
        "scratch buffers must stay pointer-stable"
    );

    // single-session path: step_into with a warmed logits buffer also
    // runs allocation-free (session scratch + caller-owned logits)
    drop(refs);
    let sess = &mut sessions[0];
    let mut logits = Vec::new();
    for t in 0..4u32 {
        sess.step_into(20 + t, &mut logits);
    }
    let before = allocs();
    for t in 0..16u32 {
        sess.step_into(30 + t, &mut logits);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "steady-state step_into allocated {delta} times in 16 steps"
    );
}
