//! Cross-language parity: the rust native engine must reproduce the
//! JAX model's outputs on the fixed golden input (artifacts/golden.mcwt,
//! written by python/compile/aot.py at build time).
//!
//! These tests are skipped (not failed) when artifacts/ has not been
//! built, so `cargo test` works pre-`make artifacts`.

use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::moe::model::{ForwardOpts, NullSink};
use mc_moe::moe::{MoeModel, WeightFile};

fn load() -> Option<(ModelConfig, MoeModel, WeightFile)> {
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json")).ok()?;
    let wf = WeightFile::load(&dir.join("weights.mcwt")).ok()?;
    let golden = WeightFile::load(&dir.join("golden.mcwt")).ok()?;
    let model = MoeModel::load_f32(&cfg, wf).ok()?;
    Some((cfg, model, golden))
}

fn golden_tokens(golden: &WeightFile) -> Vec<u32> {
    golden
        .vec1("tokens")
        .unwrap()
        .iter()
        .map(|&f| f as u32)
        .collect()
}

#[test]
fn logits_match_jax() {
    let Some((_cfg, model, golden)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let tokens = golden_tokens(&golden);
    let want = golden.mat("logits").unwrap();
    let got = model.score(&tokens);
    assert_eq!((got.rows, got.cols), (want.rows, want.cols));
    let mut max_abs = 0.0f32;
    let mut max_rel = 0.0f32;
    for (g, w) in got.data.iter().zip(&want.data) {
        max_abs = max_abs.max((g - w).abs());
        max_rel = max_rel.max((g - w).abs() / (1.0 + w.abs()));
    }
    assert!(
        max_rel < 5e-3,
        "logits diverge from JAX: max_abs={max_abs} max_rel={max_rel}"
    );
}

#[test]
fn router_probs_match_jax() {
    let Some((_cfg, model, golden)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let tokens = golden_tokens(&golden);
    let want = golden.mat("probs_l0").unwrap();
    let opts = ForwardOpts { collect_probs: true, ..Default::default() };
    let out = model.forward(&tokens, &opts, &mut NullSink);
    let got = &out.probs[0];
    let mut max_abs = 0.0f32;
    for (g, w) in got.data.iter().zip(&want.data) {
        max_abs = max_abs.max((g - w).abs());
    }
    assert!(max_abs < 2e-3, "layer-0 router probs diverge: {max_abs}");
}

#[test]
fn token_importance_matches_jax() {
    let Some((_cfg, model, golden)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let tokens = golden_tokens(&golden);
    let want = golden.vec1("importance_l0").unwrap();
    let opts = ForwardOpts { collect_importance: true, ..Default::default() };
    let out = model.forward(&tokens, &opts, &mut NullSink);
    let got = &out.importance[0];
    // importance spans orders of magnitude; compare relatively
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let rel = (g - w).abs() / (1e-3 + w.abs());
        assert!(rel < 2e-2, "importance[{i}]: got {g} want {w}");
    }
}

#[test]
fn trained_model_beats_uniform_ppl() {
    // sanity: the trained weights actually model the synthetic corpus
    let Some((cfg, model, _)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use mc_moe::data::{pack_stream, Split, TextChannel};
    use mc_moe::util::rng::Rng;
    let mut rng = Rng::new(1234);
    let text = TextChannel::new();
    let toks = pack_stream(&mut rng, &text, 256, Split::General);
    let logits = model.score(&toks);
    let mut nll = 0.0f64;
    for t in 1..toks.len() {
        let lp = mc_moe::tensor::log_softmax(logits.row(t - 1));
        nll -= lp[toks[t] as usize] as f64;
    }
    let ppl = (nll / (toks.len() - 1) as f64).exp();
    let uniform = cfg.vocab_size as f64;
    assert!(
        ppl < uniform / 4.0,
        "trained model PPL {ppl:.1} not << uniform {uniform}"
    );
}
