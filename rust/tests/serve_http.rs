//! End-to-end tests for the HTTP/SSE front end (ISSUE 7): every test
//! talks to a real `HttpServer` over a localhost socket using the
//! in-tree `serve::client`, so the full path — accept, parse, admit,
//! stream, drain — is exercised exactly as `curl` would drive it.

use std::sync::Arc;
use std::time::Duration;

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::{GenerateRequest, Server, StopCondition};
use mc_moe::moe::model::MoeModel;
use mc_moe::serve::client::{self, GenerateReply, SseStream};
use mc_moe::serve::{HttpServer, ServeConfig};
use mc_moe::util::json::Json;

mod common;
use common::random_model;

/// Generous per-read bound: turns a wedged stream into a test failure
/// instead of a suite hang, even on a descheduled CI runner.
const T: Duration = Duration::from_secs(120);

/// A model big enough that a long request decodes for hundreds of ms,
/// so admission choreography cannot lose races against it finishing
/// (same recipe as the serving_api cancellation test).
fn slow_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::test_tiny();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.d_ff = 256;
    cfg.n_layers = 4;
    cfg.max_seq = 256;
    cfg
}

fn serve(model: MoeModel, scfg: ServeConfig) -> HttpServer {
    let engine = Server::spawn(Arc::new(model), None, scfg.max_batch);
    HttpServer::bind(engine, scfg).expect("bind 127.0.0.1:0")
}

/// `{"prompt":[..],"max_new_tokens":n,"stop":"max_len"<extra>}`
fn gen_body(prompt: &[u32], max_new: usize, extra: &str) -> Vec<u8> {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"prompt\":[{}],\"max_new_tokens\":{max_new},\
         \"stop\":\"max_len\"{extra}}}",
        toks.join(",")
    )
    .into_bytes()
}

fn open_stream(
    http: &HttpServer,
    prompt: &[u32],
    max_new: usize,
    extra: &str,
    headers: &[(&str, &str)],
) -> GenerateReply {
    client::open_generate(http.addr(), &gen_body(prompt, max_new, extra),
                          headers, T)
        .expect("request reached the server")
}

fn expect_stream(reply: GenerateReply) -> SseStream {
    match reply {
        GenerateReply::Stream(s) => s,
        GenerateReply::Response(r) => {
            panic!("expected SSE stream, got {} {}", r.status, r.body_str())
        }
    }
}

fn token_of(data: &str) -> u32 {
    Json::parse(data).expect("token frame is JSON")
        .opt("token").expect("token field")
        .as_usize().expect("token id") as u32
}

/// Drain a stream to its terminal frame: (tokens, terminal event name).
fn drain_stream(s: &mut SseStream) -> (Vec<u32>, String) {
    let mut tokens = Vec::new();
    while let Some(ev) = s.next_event().expect("stream read") {
        match ev.name.as_str() {
            "token" => tokens.push(token_of(&ev.data)),
            terminal => return (tokens, terminal.to_string()),
        }
    }
    panic!("stream closed without a terminal done/cancelled frame");
}

#[test]
fn sse_and_json_modes_match_in_process_submit() {
    let cfg = ModelConfig::test_tiny();
    let prompt = vec![1u32, 5, 80, 3];

    // ground truth: the same request through the in-process API on an
    // identically-seeded model
    let expected = {
        let engine = Server::spawn(Arc::new(random_model(&cfg, 42)), None, 2);
        let h = engine.submit(
            GenerateRequest::greedy(prompt.clone(), 8)
                .with_stop(StopCondition::MaxLen));
        let done = h.wait().expect("in-process completion");
        engine.shutdown();
        done.tokens
    };
    assert_eq!(expected.len(), 8);

    let http = serve(random_model(&cfg, 42), ServeConfig {
        port: 0,
        max_conns: 4,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 2,
        ..ServeConfig::default()
    });

    // streaming: SSE tokens arrive in order and the done frame agrees
    let mut stream = expect_stream(open_stream(&http, &prompt, 8, "", &[]));
    let mut tokens = Vec::new();
    let mut done_data = None;
    while let Some(ev) = stream.next_event().expect("sse read") {
        match ev.name.as_str() {
            "token" => tokens.push(token_of(&ev.data)),
            "done" => done_data = Some(ev.data),
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(tokens, expected, "SSE tokens match in-process submit");
    let done = Json::parse(&done_data.expect("done frame")).unwrap();
    let done_tokens: Vec<u32> = done.opt("tokens").unwrap()
        .as_arr().unwrap().iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(done_tokens, expected, "done frame repeats the tokens");
    assert_eq!(done.opt("finish").unwrap().as_str().unwrap(), "max_tokens");

    // non-streaming: one JSON completion, same tokens
    let resp = match open_stream(&http, &prompt, 8, ",\"stream\":false", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("stream:false must not stream"),
    };
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let body = Json::parse(&resp.body_str()).unwrap();
    let got: Vec<u32> = body.opt("tokens").unwrap()
        .as_arr().unwrap().iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(got, expected, "JSON mode matches in-process submit");

    // observability endpoints on the same server
    let health = client::request(http.addr(), "GET", "/healthz", &[], b"", T)
        .unwrap();
    assert_eq!(health.status, 200);
    assert!(health.body_str().contains("\"status\":\"ok\""));
    let metrics = client::request(http.addr(), "GET", "/metrics", &[], b"", T)
        .unwrap();
    assert_eq!(metrics.status, 200);
    assert!(metrics.header("content-type").unwrap()
        .starts_with("text/plain; version=0.0.4"));
    let text = metrics.body_str();
    assert!(text.contains("# TYPE mc_requests_completed counter"), "{text}");
    assert!(text.contains("# TYPE mc_ttft_ms_window summary"), "{text}");
    assert!(text.contains("mc_ttft_ms_window{quantile=\"0.99\"}"), "{text}");
    assert!(text.contains("# TYPE mc_ttft_ms histogram"), "{text}");
    assert!(text.contains("mc_ttft_ms_bucket{le=\"+Inf\"}"), "{text}");
    assert!(text.contains("mc_build_info{version=\""), "{text}");
    let missing = client::request(http.addr(), "GET", "/nope", &[], b"", T)
        .unwrap();
    assert_eq!(missing.status, 404);

    let report = http.shutdown();
    assert!(report.drained, "no in-flight streams left to drain");
}

#[test]
fn shed_returns_429_with_retry_after_low_priority_first() {
    // max_batch=1, shed depth 2: thresholds are low=1, normal=2,
    // high=4 queued streams (mirrors the admission unit test, but
    // through real sockets)
    let http = serve(random_model(&slow_cfg(), 7), ServeConfig {
        port: 0,
        max_conns: 8,
        max_streams_per_tenant: 0,
        shed_queue_depth: 2,
        max_batch: 1,
        ..ServeConfig::default()
    });
    let prompt = [1u32, 5, 80, 3];

    // A occupies the only slot; confirm it is decoding before queuing
    let mut a = expect_stream(open_stream(&http, &prompt, 240, "", &[]));
    let first = a.next_event().expect("read").expect("first frame");
    assert_eq!(first.name, "token");
    // B queues behind it (queued estimate now 1)
    let b = expect_stream(open_stream(&http, &prompt, 240, "", &[]));

    // low priority sheds first: threshold 1 <= queued 1
    let low = match open_stream(&http, &prompt, 240,
                                ",\"priority\":\"low\"", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("low must shed at queued=1"),
    };
    assert_eq!(low.status, 429, "{}", low.body_str());
    let retry: u64 = low.header("retry-after")
        .expect("429 carries Retry-After")
        .parse().expect("Retry-After is numeric seconds");
    assert!(retry >= 1);

    // normal still admits at queued=1...
    let c = expect_stream(open_stream(&http, &prompt, 240, "", &[]));
    // ...and sheds at queued=2
    let shed = match open_stream(&http, &prompt, 240, "", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("normal must shed at queued=2"),
    };
    assert_eq!(shed.status, 429);
    assert!(shed.header("retry-after").is_some());

    // high priority rides through until twice the configured depth
    let d = expect_stream(open_stream(&http, &prompt, 240,
                                      ",\"priority\":\"high\"", &[]));

    let m = http.metrics();
    assert_eq!(m.requests_shed.load(std::sync::atomic::Ordering::Relaxed), 2);

    // abandon everything; the server must cancel all four and drain
    a.abort();
    b.abort();
    c.abort();
    d.abort();
    let report = http.shutdown();
    assert!(report.drained, "aborted streams must not pin the drain");
}

#[test]
fn tenant_cap_holds_while_other_tenant_proceeds() {
    let http = serve(random_model(&slow_cfg(), 8), ServeConfig {
        port: 0,
        max_conns: 8,
        max_streams_per_tenant: 1,
        shed_queue_depth: 0,
        max_batch: 2,
        ..ServeConfig::default()
    });
    let prompt = [1u32, 5, 80, 3];
    let acme = [("X-Tenant", "acme")];

    // acme's one allowed stream
    let mut a = expect_stream(open_stream(&http, &prompt, 240, "", &acme));
    let first = a.next_event().expect("read").expect("first frame");
    assert_eq!(first.name, "token");

    // acme's second concurrent stream is refused with Retry-After
    let busy = match open_stream(&http, &prompt, 4, "", &acme) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("tenant cap must refuse"),
    };
    assert_eq!(busy.status, 429, "{}", busy.body_str());
    assert!(busy.header("retry-after").is_some());
    assert!(busy.body_str().contains("acme"), "{}", busy.body_str());

    // a different tenant proceeds at the same moment
    let globex = match open_stream(&http, &prompt, 4, ",\"stream\":false",
                                   &[("X-Tenant", "globex")]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => unreachable!(),
    };
    assert_eq!(globex.status, 200, "{}", globex.body_str());
    assert!(globex.body_str().contains("\"tokens\":["));

    assert_eq!(http.metrics().requests_tenant_limited
                   .load(std::sync::atomic::Ordering::Relaxed), 1);

    // once acme's stream ends (client disconnect), its slot frees;
    // poll because the server notices the hang-up asynchronously
    a.abort();
    let mut freed = false;
    for _ in 0..1500 {
        let again = match open_stream(&http, &prompt, 2, ",\"stream\":false",
                                      &acme) {
            GenerateReply::Response(r) => r,
            GenerateReply::Stream(_) => unreachable!(),
        };
        if again.status == 200 {
            freed = true;
            break;
        }
        assert_eq!(again.status, 429, "{}", again.body_str());
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(freed, "tenant slot never freed after the disconnect");

    let report = http.shutdown();
    assert!(report.drained);
}

#[test]
fn drain_finishes_inflight_and_refuses_new() {
    let http = serve(random_model(&slow_cfg(), 9), ServeConfig {
        port: 0,
        max_conns: 8,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 1,
        ..ServeConfig::default()
    });
    let prompt = [1u32, 5, 80, 3];

    let mut a = expect_stream(open_stream(&http, &prompt, 120, "", &[]));
    let first = a.next_event().expect("read").expect("first frame");
    assert_eq!(first.name, "token");
    let mut tokens = vec![token_of(&first.data)];

    // begin drain over the wire
    let drain = client::request(http.addr(), "POST", "/admin/drain", &[],
                                b"", T).unwrap();
    assert_eq!(drain.status, 200);
    assert!(drain.body_str().contains("\"draining\":true"));
    assert!(http.draining());

    // health reflects it; new generate requests are refused with 503
    let health = client::request(http.addr(), "GET", "/healthz", &[], b"", T)
        .unwrap();
    assert!(health.body_str().contains("\"status\":\"draining\""));
    let refused = match open_stream(&http, &prompt, 4, "", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("draining server must refuse"),
    };
    assert_eq!(refused.status, 503, "{}", refused.body_str());
    assert!(refused.header("retry-after").is_some());

    // the in-flight stream still delivers every token it was promised
    let (rest, terminal) = drain_stream(&mut a);
    tokens.extend(rest);
    assert_eq!(terminal, "done", "drain must not cancel in-flight work");
    assert_eq!(tokens.len(), 120, "drain lost streamed tokens");

    let report = http.shutdown();
    assert!(report.drained);
}

#[test]
fn malformed_and_oversized_bodies_do_not_wedge() {
    let http = serve(random_model(&ModelConfig::test_tiny(), 10), ServeConfig {
        port: 0,
        max_conns: 4,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 1,
        max_body_bytes: 1024,
        ..ServeConfig::default()
    });

    // invalid JSON → 400 naming the problem
    let bad = client::request(http.addr(), "POST", "/v1/generate", &[],
                              b"this is not json", T).unwrap();
    assert_eq!(bad.status, 400, "{}", bad.body_str());
    assert!(bad.body_str().contains("JSON"), "{}", bad.body_str());

    // valid JSON, missing required field → 400 naming the field
    let missing = client::request(http.addr(), "POST", "/v1/generate", &[],
                                  b"{\"max_new_tokens\":4}", T).unwrap();
    assert_eq!(missing.status, 400);
    assert!(missing.body_str().contains("prompt"));

    // oversized body → 413, refused before buffering
    let huge = vec![b'x'; 8 << 10];
    let too_big = client::request(http.addr(), "POST", "/v1/generate", &[],
                                  &huge, T).unwrap();
    assert_eq!(too_big.status, 413, "{}", too_big.body_str());

    // wrong method on a real route → 404 (no wedge, no panic)
    let wrong = client::request(http.addr(), "GET", "/v1/generate", &[],
                                b"", T).unwrap();
    assert_eq!(wrong.status, 404);

    // after all of that the server still serves work
    let ok = match open_stream(&http, &[1, 5, 80, 3], 3,
                               ",\"stream\":false", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => unreachable!(),
    };
    assert_eq!(ok.status, 200, "{}", ok.body_str());
    assert_eq!(http.metrics().http_bad_requests
                   .load(std::sync::atomic::Ordering::Relaxed), 4);

    let report = http.shutdown();
    assert!(report.drained);
}

#[test]
fn memory_budget_exhaustion_returns_503_with_retry_after() {
    use mc_moe::coordinator::ServerConfig;

    // a 1-byte ceiling: the static baseline alone exceeds it, so every
    // session admission must refuse at the connection layer
    let cfg = ModelConfig::test_tiny();
    let engine = Server::spawn_cfg(
        Arc::new(random_model(&cfg, 13)),
        None,
        ServerConfig {
            max_batch: 1,
            mem_budget: Some(1),
            ..ServerConfig::default()
        },
    );
    let http = HttpServer::bind(engine, ServeConfig {
        port: 0,
        max_conns: 2,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 1,
        ..ServeConfig::default()
    })
    .expect("bind 127.0.0.1:0");

    let refused = match open_stream(&http, &[1, 5, 80, 3], 4,
                                    ",\"stream\":false", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("over-budget request must refuse"),
    };
    assert_eq!(refused.status, 503, "{}", refused.body_str());
    let retry: u64 = refused.header("retry-after")
        .expect("memory 503 carries Retry-After")
        .parse().expect("numeric seconds");
    assert!(retry >= 1);
    assert!(refused.body_str().contains("memory budget"),
            "{}", refused.body_str());
    assert!(http.metrics().mem_admission_rejected
                .load(std::sync::atomic::Ordering::Relaxed) >= 1);

    let report = http.shutdown();
    assert!(report.drained, "a refused request leaves nothing in flight");
}

/// Read one full HTTP response (status, `Connection` header value,
/// body) off a raw keep-alive socket. Byte-wise header reads are fine
/// here: the client waits for the complete response before sending the
/// next request, so nothing beyond this response is ever in flight.
fn read_keep_alive_response(sock: &mut std::net::TcpStream)
                            -> (u16, String, String) {
    use std::io::Read as _;
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        let n = sock.read(&mut byte).expect("header read");
        assert!(n > 0, "peer closed mid-headers");
        head.push(byte[0]);
    }
    let head = String::from_utf8_lossy(&head).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let mut clen = 0usize;
    let mut conn = String::new();
    for line in head.lines().skip(1) {
        let Some((k, v)) = line.split_once(':') else { continue };
        match k.trim().to_ascii_lowercase().as_str() {
            "content-length" => clen = v.trim().parse().expect("length"),
            "connection" => conn = v.trim().to_ascii_lowercase(),
            _ => {}
        }
    }
    let mut body = vec![0u8; clen];
    sock.read_exact(&mut body).expect("body read");
    (status, conn, String::from_utf8_lossy(&body).to_string())
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_socket() {
    use std::io::{Read as _, Write as _};

    let http = serve(random_model(&ModelConfig::test_tiny(), 12), ServeConfig {
        port: 0,
        max_conns: 2,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 1,
        ..ServeConfig::default()
    });

    let body = gen_body(&[1, 5, 80, 3], 4, ",\"stream\":false");
    let head = |conn: &str| {
        format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: {conn}\r\n\
             Content-Length: {}\r\n\r\n",
            body.len()
        )
    };

    let mut sock = std::net::TcpStream::connect(http.addr()).unwrap();
    sock.set_read_timeout(Some(T)).unwrap();

    // the tokens array is the deterministic part of a completion body
    // (id / ttft_ms / total_ms legitimately vary per request)
    let tokens_of = |body: &str| -> String {
        let start = body.find("\"tokens\":[").expect("tokens array");
        let end = body[start..].find(']').expect("closing bracket") + start;
        body[start..=end].to_string()
    };

    // two sequential completions over the SAME socket: both 200, both
    // advertising keep-alive, and (greedy, same prompt) identical
    sock.write_all(head("keep-alive").as_bytes()).unwrap();
    sock.write_all(&body).unwrap();
    let (s1, c1, b1) = read_keep_alive_response(&mut sock);
    assert_eq!(s1, 200, "{b1}");
    assert_eq!(c1, "keep-alive", "opt-in must be honored");
    assert!(b1.contains("\"tokens\":["), "{b1}");

    sock.write_all(head("keep-alive").as_bytes()).unwrap();
    sock.write_all(&body).unwrap();
    let (s2, c2, b2) = read_keep_alive_response(&mut sock);
    assert_eq!(s2, 200, "{b2}");
    assert_eq!(c2, "keep-alive");
    assert_eq!(tokens_of(&b2), tokens_of(&b1),
               "same socket, same greedy request, same tokens");

    // without the opt-in header the server answers and closes (the
    // historical default): the next read sees EOF
    sock.write_all(head("close").as_bytes()).unwrap();
    sock.write_all(&body).unwrap();
    let (s3, c3, b3) = read_keep_alive_response(&mut sock);
    assert_eq!(s3, 200, "{b3}");
    assert_eq!(c3, "close");
    assert_eq!(tokens_of(&b3), tokens_of(&b1));
    let mut probe = [0u8; 1];
    assert_eq!(sock.read(&mut probe).expect("clean EOF"), 0,
               "server must close after a Connection: close response");

    // all three requests rode one TCP connection
    assert_eq!(http.metrics().http_conns_accepted
                   .load(std::sync::atomic::Ordering::Relaxed), 1);

    drop(sock);
    let report = http.shutdown();
    assert!(report.drained);
}

/// The flight recorder's HTTP windows (DESIGN.md §9) against a live
/// server: arm tracing over the wire, stream one request on an
/// offloaded model, and the Chrome trace must cover every stage —
/// admission, queue wait, prefill, per-step decode, sampling, SSE
/// writes, and at least one demand expert fetch — while
/// `/debug/experts` reports per-layer routing heat and residency.
#[test]
fn debug_trace_and_experts_expose_live_request() {
    use mc_moe::moe::qz;
    use mc_moe::offload::{self, PrefetchMode};

    // offloaded at half budget with prefetch off: every first touch
    // of an expert is a demand fetch the trace must show
    let cfg = ModelConfig::test_tiny();
    let m = random_model(&cfg, 51);
    let path = std::env::temp_dir()
        .join(format!("serve_trace_{}.mcqz", std::process::id()));
    qz::save(&path, &m).unwrap();
    let expert_bytes: usize = m.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();
    drop(m);
    let cached = offload::load_cached(&path, expert_bytes / 2,
                                      PrefetchMode::Off).unwrap();
    let http = serve(cached, ServeConfig {
        port: 0,
        max_conns: 4,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 2,
        ..ServeConfig::default()
    });

    // arm + reset the recorder over the wire
    let armed = client::request(http.addr(), "GET",
                                "/debug/trace?enable=1&clear=1", &[], b"", T)
        .unwrap();
    assert_eq!(armed.status, 200, "{}", armed.body_str());

    // one full streamed request while armed
    let mut s = expect_stream(open_stream(&http, &[1, 5, 80, 3], 8, "", &[]));
    let (tokens, terminal) = drain_stream(&mut s);
    assert_eq!(terminal, "done");
    assert_eq!(tokens.len(), 8);

    // the trace window: valid Chrome JSON covering the whole path
    let trace = client::request(http.addr(), "GET", "/debug/trace", &[],
                                b"", T).unwrap();
    assert_eq!(trace.status, 200);
    assert!(trace.header("content-type").unwrap()
        .starts_with("application/json"));
    let json = Json::parse(&trace.body_str()).expect("Chrome trace JSON");
    let events = json.opt("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let names: std::collections::HashSet<&str> = events.iter()
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    for required in ["admission", "queue_wait", "prefill", "decode_step",
                     "token_sampled", "sse_write", "expert_fetch",
                     "layer_routing", "odp_dispatch", "first_token"] {
        assert!(names.contains(required),
                "trace must cover {required}; saw {names:?}");
    }
    // spans carry durations, instants don't
    let prefill = events.iter()
        .find(|e| e.get("name").unwrap().as_str().unwrap() == "prefill")
        .unwrap();
    assert!(prefill.get("dur").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(prefill.get("ph").unwrap().as_str().unwrap(), "X");

    // the expert window: per-layer heat joined with live residency
    let experts = client::request(http.addr(), "GET", "/debug/experts", &[],
                                  b"", T).unwrap();
    assert_eq!(experts.status, 200);
    let j = Json::parse(&experts.body_str()).expect("experts JSON");
    assert!(j.get("tracing").unwrap().as_bool().unwrap());
    assert_eq!(j.get("n_layers").unwrap().as_usize().unwrap(), cfg.n_layers);
    let layers = j.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), cfg.n_layers);
    let mut evicted_somewhere = false;
    for layer in layers {
        assert!(layer.get("tokens").unwrap().as_usize().unwrap() > 0,
                "every layer routed the request's tokens");
        let rows = layer.get("experts").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), cfg.n_experts);
        let activations: usize = rows.iter()
            .map(|r| r.get("activations").unwrap().as_usize().unwrap())
            .sum();
        assert!(activations > 0);
        evicted_somewhere |= rows.iter()
            .any(|r| !r.get("resident").unwrap().as_bool().unwrap());
    }
    // residency comes from the cache, and half the budget means the
    // model cannot be fully resident
    assert!(evicted_somewhere, "half-budget cache cannot hold every expert");

    // last_ms=0 excludes everything that already ended
    let empty = client::request(http.addr(), "GET", "/debug/trace?last_ms=0",
                                &[], b"", T).unwrap();
    let j = Json::parse(&empty.body_str()).unwrap();
    assert!(j.opt("traceEvents").unwrap().as_arr().unwrap().len()
                < events.len());

    // disarm + clear: both windows drain back to empty
    let off = client::request(http.addr(), "GET",
                              "/debug/trace?enable=0&clear=1", &[], b"", T)
        .unwrap();
    assert_eq!(off.status, 200);
    let cleared = client::request(http.addr(), "GET",
                                  "/debug/experts?clear=1", &[], b"", T)
        .unwrap();
    assert!(!Json::parse(&cleared.body_str()).unwrap()
        .get("tracing").unwrap().as_bool().unwrap());
    let after = client::request(http.addr(), "GET", "/debug/trace", &[],
                                b"", T).unwrap();
    assert!(Json::parse(&after.body_str()).unwrap()
        .opt("traceEvents").unwrap().as_arr().unwrap().is_empty());

    let report = http.shutdown();
    assert!(report.drained);
    std::fs::remove_file(&path).ok();
}

/// Prometheus scrapes must stay valid and non-blocking while streams
/// are actively decoding (ISSUE 10 satellite): three scrapers hammer
/// `/metrics` concurrently with two live SSE streams.
#[test]
fn metrics_scrape_stays_valid_under_streaming_load() {
    let http = serve(random_model(&slow_cfg(), 14), ServeConfig {
        port: 0,
        max_conns: 8,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 2,
        ..ServeConfig::default()
    });
    let prompt = [1u32, 5, 80, 3];

    // two long streams take the batch; confirm both are decoding
    let mut a = expect_stream(open_stream(&http, &prompt, 120, "", &[]));
    assert_eq!(a.next_event().unwrap().unwrap().name, "token");
    let mut b = expect_stream(open_stream(&http, &prompt, 120, "", &[]));

    let addr = http.addr();
    let scrapers: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(move || {
                for _ in 0..20 {
                    let m = client::request(addr, "GET", "/metrics", &[],
                                            b"", T).expect("scrape");
                    assert_eq!(m.status, 200);
                    let text = m.body_str();
                    // a mid-flight scrape is still a complete, valid
                    // exposition: families, summaries, histograms
                    assert!(text.contains(
                        "# TYPE mc_requests_completed counter"), "{text}");
                    assert!(text.contains("mc_ttft_ms_bucket{le=\"+Inf\"}"),
                            "{text}");
                    assert!(text.contains("mc_build_info{version=\""),
                            "{text}");
                    assert!(text.ends_with('\n'), "exposition must end in \\n");
                }
            })
        })
        .collect();
    for s in scrapers {
        s.join().expect("scraper thread");
    }

    // the streams were untouched by the scrape storm
    let (ta, term_a) = drain_stream(&mut a);
    let (tb, term_b) = drain_stream(&mut b);
    assert_eq!((term_a.as_str(), term_b.as_str()), ("done", "done"));
    assert_eq!(ta.len() + 1, 120, "stream A lost tokens under scraping");
    assert_eq!(tb.len(), 120, "stream B lost tokens under scraping");

    let report = http.shutdown();
    assert!(report.drained);
}

#[test]
fn mid_stream_disconnect_cancels_and_frees_slot() {
    let http = serve(random_model(&slow_cfg(), 11), ServeConfig {
        port: 0,
        max_conns: 4,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 1,
        ..ServeConfig::default()
    });
    let prompt = [1u32, 5, 80, 3];

    // a long stream takes the only batch slot...
    let mut a = expect_stream(open_stream(&http, &prompt, 240, "", &[]));
    let first = a.next_event().expect("read").expect("first frame");
    assert_eq!(first.name, "token");
    // ...and the client vanishes mid-stream
    a.abort();

    // the dropped connection must cancel the request and free its
    // slot: a second request can only complete if it did
    let next = match open_stream(&http, &prompt, 3, ",\"stream\":false", &[]) {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => unreachable!(),
    };
    assert_eq!(next.status, 200,
               "slot freed after disconnect: {}", next.body_str());

    let m = http.metrics();
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(m.client_disconnects.load(Relaxed), 1);
    assert_eq!(m.requests_cancelled.load(Relaxed), 1);

    let report = http.shutdown();
    assert!(report.drained, "no stuck streams after a disconnect");
    assert_eq!(report.inflight_at_start, 0,
               "everything had retired before shutdown began");
}
