//! Integration: the full MC pipeline on the *trained* model —
//! calibrate -> GPTQ zoo -> PMQ allocation -> assembled model -> eval.
//! Asserts the paper's qualitative claims hold on this substrate:
//!   * PMQ @ 2.5 avg bits beats uniform 2-bit on PPL
//!   * mixed allocation differs from uniform (the IP actually chooses)
//!   * ODP protection recovers part of weight-only pruning's PPL hit
//!     at (almost) the same compression ratio
//!
//! Skipped when artifacts/ hasn't been built.

use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::data::Split;
use mc_moe::eval::perplexity;
use mc_moe::moe::model::OdpPolicy;
use mc_moe::moe::{qz, MoeModel, WeightFile};
use mc_moe::odp;
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{Workbench, WorkbenchConfig};
use mc_moe::quant::quantize_rtn;

mod common;

fn workbench() -> Option<Workbench> {
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json")).ok()?;
    let wf = WeightFile::load(&dir.join("weights.mcwt")).ok()?;
    let fp = MoeModel::load_f32(&cfg, wf).ok()?;
    Workbench::build(
        fp,
        WorkbenchConfig {
            calib_seqs: 4,
            calib_len: 192,
            probe_seqs: 1,
            fast_eps: false,
            ..Default::default()
        },
    )
    .ok()
}

fn ppl(m: &MoeModel, odp: Option<&OdpPolicy>) -> f64 {
    perplexity(m, Split::Text, 9100, 2, 192, odp).ppl
}

#[test]
fn full_pipeline_reproduces_paper_shapes() {
    let Some(wb) = workbench() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = wb.fp.cfg.n_experts;

    let fp_ppl = ppl(&wb.fp, None);

    // --- uniform 2-bit vs PMQ @ 2.5 avg (paper Tab. 2's headline) ---
    let uni2 = wb.compress_uniform(2).unwrap();
    let uni2_ppl = ppl(&uni2, None);
    let (pmq25, alloc) = wb
        .compress(Allocator::Pmq, 5 * n / 2, PmqHyper::default())
        .unwrap();
    let pmq25_ppl = ppl(&pmq25, None);
    assert!(fp_ppl < uni2_ppl, "fp {fp_ppl} vs uni2 {uni2_ppl}");
    assert!(
        pmq25_ppl < uni2_ppl,
        "PMQ-2.5b PPL {pmq25_ppl} must beat uniform-2b {uni2_ppl}"
    );
    // the IP must actually mix widths (not collapse to uniform)
    let hist = alloc.histogram();
    assert!(hist[0] > 0 && hist[2] > 0, "degenerate allocation {hist:?}");

    // --- PMQ @ 2.0 beats uniform 2-bit at the same nominal budget ---
    let (pmq20, _) = wb.compress(Allocator::Pmq, 2 * n, PmqHyper::default()).unwrap();
    let pmq20_ppl = ppl(&pmq20, None);
    assert!(
        pmq20_ppl < uni2_ppl * 1.02,
        "PMQ-2.0b {pmq20_ppl} should be <= uniform-2b {uni2_ppl}"
    );

    // --- ODP: protection recovers weight-only loss (paper Fig. 7) ---
    let weight_only = odp::weight_only(&wb.cal);
    let protected = odp::odp(&wb.cal, 0.02);
    let r_wo = perplexity(&pmq25, Split::Text, 9100, 2, 192, Some(&weight_only));
    let r_prot = perplexity(&pmq25, Split::Text, 9100, 2, 192, Some(&protected));
    assert!(
        r_prot.ppl <= r_wo.ppl * 1.005,
        "protection must not hurt: {} vs {}",
        r_prot.ppl,
        r_wo.ppl
    );
    // compression ratio nearly unchanged (2% protection)
    let cr_wo = r_wo.stats.compression_ratio();
    let cr_prot = r_prot.stats.compression_ratio();
    assert!(
        cr_prot > cr_wo - 0.03,
        "protection should barely cost compression: {cr_prot} vs {cr_wo}"
    );
    assert!(cr_wo > 0.05, "median threshold should prune >5%: {cr_wo}");

    // --- storage accounting: 2.5-bit experts are ~4-12x smaller ---
    let fp_expert: usize = wb.fp.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();
    let mc_expert: usize = pmq25.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();
    let ratio = mc_expert as f64 / fp_expert as f64;
    assert!(
        (0.08..0.25).contains(&ratio),
        "expert compression ratio {ratio} out of expected band"
    );
}

#[test]
fn binary_experts_degrade_gracefully() {
    let Some(wb) = workbench() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // all-1-bit is the extreme; it must still produce finite PPL and
    // be strictly worse than 3-bit
    let uni1 = wb.compress_uniform(1).unwrap();
    let uni3 = wb.compress_uniform(3).unwrap();
    let p1 = ppl(&uni1, None);
    let p3 = ppl(&uni3, None);
    assert!(p1.is_finite() && p3.is_finite());
    assert!(p3 < p1, "3-bit {p3} must beat 1-bit {p1}");
}

#[test]
fn mcqz_v1_to_v2_roundtrip_is_bit_exact() {
    // not artifact-gated: a legacy v1 file must load, re-save as the
    // segmented v2 layout, and reload with bit-identical outputs and
    // storage accounting
    let cfg = ModelConfig::test_tiny();
    let mut m = common::random_model(&cfg, 77);
    for layer in m.layers.iter_mut() {
        for (e, bits) in [(0usize, 2usize), (1, 3), (2, 1)] {
            let ex = &mut layer.experts[e];
            ex.w1 = quantize_rtn(&ex.w1.dequantize(), bits);
            ex.w3 = quantize_rtn(&ex.w3.dequantize(), bits);
            ex.w2 = quantize_rtn(&ex.w2.dequantize(), bits);
        }
    }
    let pid = std::process::id();
    let p1 = std::env::temp_dir().join(format!("qp_v1_{pid}.mcqz"));
    let p2 = std::env::temp_dir().join(format!("qp_v2_{pid}.mcqz"));
    qz::save_v1(&p1, &m).unwrap();
    let from_v1 = qz::load(&p1).unwrap();
    qz::save(&p2, &from_v1).unwrap();
    let from_v2 = qz::load(&p2).unwrap();
    let toks: Vec<u32> = (1..25).collect();
    let want = m.score(&toks);
    assert_eq!(want.data, from_v1.score(&toks).data, "v1 reload drifted");
    assert_eq!(want.data, from_v2.score(&toks).data,
               "v1 -> v2 roundtrip must be bit-exact");
    assert_eq!(from_v1.storage_bytes(), from_v2.storage_bytes());
    assert_eq!(from_v1.cfg, from_v2.cfg);
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn pmq_not_worse_than_single_metric_baselines() {
    let Some(wb) = workbench() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = wb.fp.cfg.n_experts;
    let budget = 2 * n; // the regime where metrics differ most
    let hyper = PmqHyper::default();
    let pmq = ppl(&wb.compress(Allocator::Pmq, budget, hyper).unwrap().0, None);
    // PMQ should beat the worst single-metric baseline
    let baselines: Vec<f64> = [Allocator::Weight, Allocator::Frequency,
                               Allocator::Random(3)]
        .iter()
        .map(|&s| ppl(&wb.compress(s, budget, hyper).unwrap().0, None))
        .collect();
    let worst = baselines.iter().cloned().fold(0.0, f64::max);
    assert!(
        pmq < worst,
        "PMQ {pmq} should beat the worst single-metric baseline {worst} \
         (baselines: {baselines:?})"
    );
}
