//! Integration tests for the unified request API (ISSUE 2): one
//! `GenerateRequest`/`SamplingParams`/`StopCondition` surface across
//! `McEngine` (single-request), `Batcher` (fused continuous
//! batching), and `Server` (threaded streaming + cancellation).

use std::sync::Arc;
use std::time::Duration;

use mc_moe::config::{ModelConfig, EOS};
use mc_moe::coordinator::{
    Batcher, FinishReason, GenerateRequest, McEngine, Metrics, Priority,
    SamplingParams, Server, StopCondition, StreamEvent,
};
use mc_moe::moe::model::MoeModel;

mod common;
use common::random_model;

fn shared_model(seed: u64) -> Arc<MoeModel> {
    Arc::new(random_model(&ModelConfig::test_tiny(), seed))
}

fn batcher_tokens(model: Arc<MoeModel>, req: GenerateRequest, max_batch: usize)
                  -> Vec<u32> {
    let metrics = Metrics::new();
    let mut b = Batcher::new(model, None, max_batch);
    let h = b.submit(req);
    b.run_to_completion(&metrics);
    h.wait().expect("completion").tokens
}

#[test]
fn same_seed_sampling_matches_across_engine_and_batcher() {
    // the tentpole guarantee: one Sampler, so the single-request
    // engine path and the fused batcher path emit identical tokens
    // for the same SamplingParams + seed
    let model = shared_model(11);
    let prompt = vec![1u32, 5, 80, 3, 44, 9];
    for sampling in [
        SamplingParams::greedy(),
        SamplingParams::temperature(0.8, 42),
        SamplingParams { temperature: 1.2, top_k: 8, top_p: 0.95, seed: 7 },
    ] {
        let req = GenerateRequest::greedy(prompt.clone(), 10)
            .with_sampling(sampling.clone())
            .with_stop(StopCondition::MaxLen);
        let engine =
            McEngine::new(random_model(&ModelConfig::test_tiny(), 11),
                          None, None);
        let via_engine = engine.generate(&req).unwrap().tokens;
        let via_batcher = batcher_tokens(model.clone(), req.clone(), 1);
        assert_eq!(via_engine, via_batcher, "params {sampling:?}");
        // and the batcher is batch-width invariant for seeded sampling
        let via_wide = {
            let metrics = Metrics::new();
            let mut b = Batcher::new(model.clone(), None, 3);
            let h = b.submit(req.clone());
            let _f1 = b.submit(GenerateRequest::greedy(vec![2, 6, 81, 3], 10)
                .with_stop(StopCondition::MaxLen));
            let _f2 = b.submit(GenerateRequest::greedy(vec![3, 7, 82, 3], 10)
                .with_stop(StopCondition::MaxLen));
            b.run_to_completion(&metrics);
            h.wait().expect("completion").tokens
        };
        assert_eq!(via_engine, via_wide, "params {sampling:?} (batch 3)");
    }
}

#[test]
fn same_seed_same_tokens_different_seed_diverges() {
    let model = shared_model(13);
    let mk = |seed| {
        GenerateRequest::greedy(vec![1, 5, 80, 3], 12)
            .with_sampling(SamplingParams::temperature(2.0, seed))
            .with_stop(StopCondition::MaxLen)
    };
    let a = batcher_tokens(model.clone(), mk(5), 2);
    let b = batcher_tokens(model.clone(), mk(5), 2);
    let c = batcher_tokens(model, mk(6), 2);
    assert_eq!(a, b, "same seed must replay identically");
    assert_ne!(a, c, "different seeds must diverge at temp 2.0");
}

#[test]
fn stop_conditions_eos_stopset_maxlen() {
    let model = shared_model(17);
    let prompt = vec![1u32, 5, 80, 3];
    // max-len: exactly max_new_tokens, finish MaxTokens, EOS ignored
    let ml = GenerateRequest::greedy(prompt.clone(), 6)
        .with_stop(StopCondition::MaxLen);
    let metrics = Metrics::new();
    let mut b = Batcher::new(model.clone(), None, 1);
    let h = b.submit(ml);
    let done = b.run_to_completion(&metrics);
    assert_eq!(done[0].tokens.len(), 6);
    assert_eq!(done[0].finish, FinishReason::MaxTokens);
    let greedy_tokens = h.wait().unwrap().tokens;

    // stop-set: cut at the first occurrence of a chosen stop token
    let stop_at = greedy_tokens[2];
    let first = greedy_tokens.iter().position(|&t| t == stop_at).unwrap();
    let ss = GenerateRequest::greedy(prompt.clone(), 6)
        .with_stop(StopCondition::StopTokens(vec![stop_at]));
    let mut b = Batcher::new(model.clone(), None, 1);
    let done = b.run_to_completion_after(ss, &metrics);
    assert_eq!(done.tokens, greedy_tokens[..=first].to_vec());
    assert_eq!(done.finish, FinishReason::Stop(stop_at));

    // eos: default condition stops iff the model emits EOS; emulate by
    // making EOS the stop-set and checking Eos behaves identically
    let eos_like = GenerateRequest::greedy(prompt.clone(), 6); // Eos default
    let explicit = GenerateRequest::greedy(prompt, 6)
        .with_stop(StopCondition::StopTokens(vec![EOS]));
    let mut b1 = Batcher::new(model.clone(), None, 1);
    let d1 = b1.run_to_completion_after(eos_like, &metrics);
    let mut b2 = Batcher::new(model, None, 1);
    let d2 = b2.run_to_completion_after(explicit, &metrics);
    assert_eq!(d1.tokens, d2.tokens);
}

#[test]
fn server_streams_tokens_incrementally() {
    let server = Server::spawn(shared_model(19), None, 2);
    let mut h = server.submit(
        GenerateRequest::greedy(vec![1, 5, 80, 3], 5)
            .with_stop(StopCondition::MaxLen));
    let mut streamed = Vec::new();
    let mut saw_done = false;
    while let Some(ev) = h.next_event() {
        match ev {
            StreamEvent::Token(t) => {
                assert!(!saw_done, "tokens must precede Done");
                streamed.push(t);
            }
            StreamEvent::Done(c) => {
                saw_done = true;
                assert_eq!(c.tokens, streamed);
            }
            StreamEvent::Cancelled { .. } => panic!("not cancelled"),
        }
    }
    assert!(saw_done);
    assert_eq!(streamed.len(), 5);
    server.shutdown();
}

#[test]
fn server_cancellation_frees_slot_and_admits_queued() {
    // batch=1: a long-running request holds the only slot; cancelling
    // it mid-decode must retire the session and admit the waiter.
    // A bigger-than-test_tiny model widens the decode to hundreds of
    // ms so the client-side cancel cannot lose the race against the
    // request finishing naturally on a descheduled CI runner.
    let mut cfg = ModelConfig::test_tiny();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.d_ff = 256;
    cfg.n_layers = 4;
    cfg.max_seq = 256;
    let server = Server::spawn(Arc::new(random_model(&cfg, 23)), None, 1);
    let mut long = server.submit(
        GenerateRequest::greedy(vec![1, 5, 80, 3], 240)
            .with_stop(StopCondition::MaxLen));
    // wait until it is demonstrably mid-decode (first token streamed)
    let first = long.next_event();
    assert!(matches!(first, Some(StreamEvent::Token(_))));
    let mut waiter =
        server.submit(GenerateRequest::greedy(vec![2, 6, 81, 3], 3));
    long.cancel();
    // the waiter can only complete if the cancelled session's slot was
    // freed; the bounded wait turns a hung/regressed worker into a
    // fast failure instead of a suite hang
    let done = waiter
        .wait_timeout(Duration::from_secs(120))
        .expect("queued request admitted after cancel");
    assert!(!done.tokens.is_empty());
    // the cancelled stream terminates with Cancelled, not Done
    while let Some(ev) = long.next_event() {
        if let StreamEvent::Done(_) = ev {
            panic!("cancelled request must not complete");
        }
    }
    assert!(long.was_cancelled());
    assert_eq!(
        server.metrics.requests_cancelled
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    server.shutdown();
}

#[test]
fn dropped_handle_cancels_and_frees_slot() {
    // ISSUE 7 regression: a client that drops its RequestHandle
    // mid-stream (the HTTP layer's disconnect path reduces to exactly
    // this) must retire the session and free its batch slot — a
    // waiter can only complete if it did.
    let mut cfg = ModelConfig::test_tiny();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.d_ff = 256;
    cfg.n_layers = 4;
    cfg.max_seq = 256;
    let server = Server::spawn(Arc::new(random_model(&cfg, 31)), None, 1);
    let mut victim = server.submit(
        GenerateRequest::greedy(vec![1, 5, 80, 3], 240)
            .with_stop(StopCondition::MaxLen));
    // demonstrably mid-decode before the drop
    assert!(matches!(victim.next_event(), Some(StreamEvent::Token(_))));
    drop(victim);
    let mut waiter =
        server.submit(GenerateRequest::greedy(vec![2, 6, 81, 3], 3));
    let done = waiter
        .wait_timeout(Duration::from_secs(120))
        .expect("slot freed by the dropped handle");
    assert!(!done.tokens.is_empty());
    assert_eq!(
        server.metrics.requests_cancelled
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    server.shutdown();
}

#[test]
fn priority_requests_jump_the_queue() {
    let metrics = Metrics::new();
    let mut b = Batcher::new(shared_model(29), None, 1);
    let _first = b.submit(GenerateRequest::greedy(vec![1, 5, 80, 3], 2));
    b.step(&metrics); // occupy the slot
    let low = b.submit(GenerateRequest::greedy(vec![2, 6, 81, 3], 2)
        .with_priority(Priority::Low));
    let high = b.submit(GenerateRequest::greedy(vec![3, 7, 82, 3], 2)
        .with_priority(Priority::High));
    let done = b.run_to_completion(&metrics);
    let pos = |id| done.iter().position(|c| c.id == id).unwrap();
    assert!(pos(high.id) < pos(low.id));
}

/// Helper trait so the stop-condition test reads linearly.
trait RunOne {
    fn run_to_completion_after(&mut self, req: GenerateRequest,
                               metrics: &Metrics)
                               -> mc_moe::coordinator::Completion;
}

impl RunOne for Batcher {
    fn run_to_completion_after(&mut self, req: GenerateRequest,
                               metrics: &Metrics)
                               -> mc_moe::coordinator::Completion {
        let h = self.submit(req);
        self.run_to_completion(metrics);
        h.wait().expect("completion")
    }
}
