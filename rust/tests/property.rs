//! Randomized property tests over the core invariants (proptest is not
//! vendored offline; seeded sweeps play its role).

use mc_moe::config::ModelConfig;
use mc_moe::moe::model::{ForwardOpts, NullSink, OdpPolicy};
use mc_moe::pmq::solver::{solve_brute, solve_layer, IpProblem};
use mc_moe::quant::linear::quantize_groupwise;
use mc_moe::quant::pack::{pack_levels, unpack_levels};
use mc_moe::quant::{quantize_rtn, QTensor};
use mc_moe::tensor::Mat;
use mc_moe::util::rng::Rng;

mod common;
use common::random_model;

#[test]
fn prop_pack_roundtrip_random_shapes() {
    let mut rng = Rng::new(100);
    for trial in 0..60 {
        let bits = 2 + rng.below(3); // 2..4
        let k = 1 + rng.below(300);
        let n = 1 + rng.below(20);
        let q: Vec<u32> = (0..k * n).map(|_| rng.below(1 << bits) as u32).collect();
        let packed = pack_levels(&q, k, n, bits);
        assert_eq!(unpack_levels(&packed, k, n, bits), q, "trial {trial}");
    }
}

#[test]
fn prop_quantization_error_shrinks_with_bits() {
    let mut rng = Rng::new(101);
    for trial in 0..10 {
        let k = 64 * (1 + rng.below(3));
        let n = 8 + rng.below(24);
        let std = 0.5 + rng.f32();
        let w = Mat::randn(&mut rng, k, n, std);
        let mut last = f32::INFINITY;
        for bits in [1usize, 2, 3, 4] {
            let err = w.sub(&quantize_rtn(&w, bits).dequantize()).fro_norm();
            assert!(err <= last * 1.001, "trial {trial} bits {bits}: {err} > {last}");
            last = err;
        }
    }
}

#[test]
fn prop_ip_solver_optimal_vs_brute() {
    let mut rng = Rng::new(102);
    for trial in 0..40 {
        let n = 3 + rng.below(6);
        let total = n + rng.below(2 * n + 1);
        let mut cost: Vec<[f64; 3]> = Vec::with_capacity(n);
        for _ in 0..n {
            let b = rng.f64() + 0.05;
            cost.push([
                b * (1.0 + 3.0 * rng.f64()),
                b * (0.5 + rng.f64()),
                b * rng.f64() * 0.5,
            ]);
        }
        let p = IpProblem { cost, total_bits: total, enforce_minimums: rng.f64() < 0.5 };
        match (solve_layer(&p), solve_brute(&p)) {
            (Some(bits), Some((_, want))) => {
                let got: f64 = bits.iter().enumerate()
                    .map(|(i, &j)| p.cost[i][j - 1]).sum();
                assert!((got - want).abs() < 1e-9, "trial {trial}");
            }
            (None, None) => {}
            (a, b) => panic!("trial {trial}: dp {a:?} vs brute {b:?}"),
        }
    }
}

#[test]
fn prop_odp_never_increases_expert_calls() {
    let cfg = ModelConfig::test_tiny();
    let model = random_model(&cfg, 103);
    let mut rng = Rng::new(104);
    for trial in 0..8 {
        let toks: Vec<u32> = (0..24).map(|_| rng.below(250) as u32 + 1).collect();
        let mu = rng.f32();
        let policy = OdpPolicy::Protected {
            mu: vec![mu; cfg.n_layers],
            protect_ratio: rng.f32() * 0.2,
        };
        let base = model.forward(&toks, &ForwardOpts::default(), &mut NullSink);
        let pruned = model.forward(
            &toks,
            &ForwardOpts { odp: Some(&policy), ..Default::default() },
            &mut NullSink,
        );
        assert!(pruned.stats.expert_calls <= base.stats.expert_calls,
                "trial {trial}");
        assert_eq!(
            pruned.stats.expert_calls + pruned.stats.dropped_secondary,
            base.stats.expert_calls,
            "trial {trial}: accounting"
        );
        assert!(pruned.logits.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn prop_compression_monotone_in_mu() {
    let cfg = ModelConfig::test_tiny();
    let model = random_model(&cfg, 105);
    let toks: Vec<u32> = (1..33).collect();
    let mut last = 0.0f64;
    for i in 0..6 {
        let mu = i as f32 * 0.2;
        let policy = OdpPolicy::WeightOnly { mu: vec![mu; cfg.n_layers] };
        let out = model.forward(
            &toks,
            &ForwardOpts { odp: Some(&policy), ..Default::default() },
            &mut NullSink,
        );
        let cr = out.stats.compression_ratio();
        assert!(cr >= last - 1e-12, "mu {mu}: {cr} < {last}");
        last = cr;
    }
}

#[test]
fn prop_eval_sample_gold_always_valid() {
    let mut rng = Rng::new(106);
    for _ in 0..300 {
        let task = rng.below(8);
        let s = mc_moe::data::eval_sample(&mut rng, task);
        assert!(s.gold < s.choices.len());
        assert!(!s.choices[s.gold].is_empty());
        // all choices distinct
        for i in 0..s.choices.len() {
            for j in 0..i {
                assert_ne!(s.choices[i], s.choices[j], "task {task}");
            }
        }
    }
}

#[test]
fn prop_batcher_completes_under_random_load() {
    use mc_moe::coordinator::{Batcher, GenerateRequest, Metrics};
    use std::sync::Arc;
    let cfg = ModelConfig::test_tiny();
    let model = Arc::new(random_model(&cfg, 107));
    let mut rng = Rng::new(108);
    for trial in 0..4 {
        let metrics = Metrics::new();
        let max_batch = 1 + rng.below(4);
        let mut b = Batcher::new(model.clone(), None, max_batch);
        let n = 2 + rng.below(6);
        // hold every handle until the run finishes: a dropped handle
        // cancels its request
        let _handles: Vec<_> = (0..n)
            .map(|_| {
                let plen = 2 + rng.below(8);
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.below(200) as u32 + 4).collect();
                b.submit(GenerateRequest::greedy(prompt, 1 + rng.below(6)))
            })
            .collect();
        let done = b.run_to_completion(&metrics);
        assert_eq!(done.len(), n, "trial {trial}");
    }
}

#[test]
fn prop_quantized_forward_error_bounded() {
    // quantized-model logits drift from FP but stay correlated: the
    // argmax agreement over positions must be far above chance
    let cfg = ModelConfig::test_tiny();
    let model = random_model(&cfg, 109);
    let mut q = model.clone();
    for layer in q.layers.iter_mut() {
        for e in layer.experts.iter_mut() {
            e.w1 = quantize_rtn(&e.w1.dequantize(), 3);
            e.w3 = quantize_rtn(&e.w3.dequantize(), 3);
            e.w2 = QTensor::Packed(quantize_groupwise(&e.w2.dequantize(), 3));
        }
    }
    let toks: Vec<u32> = (1..49).collect();
    let a = model.score(&toks);
    let b = q.score(&toks);
    let mut agree = 0;
    for t in 0..a.rows {
        let am = mc_moe::util::stats::argmax(a.row(t));
        let bm = mc_moe::util::stats::argmax(b.row(t));
        agree += (am == bm) as usize;
    }
    assert!(agree * 2 > a.rows, "argmax agreement {agree}/{}", a.rows);
}
