//! Expert residency acceptance (ISSUE 5): with `--expert-budget-mb`
//! below the total expert bytes, generated tokens must be **identical**
//! to the fully-resident run on both the engine path and the fused
//! batcher path; the decode workload must show real cache churn
//! (nonzero evictions) and a working predictor (prefetch hit-rate
//! > 0); and pinned experts must never be evicted mid-step.

use std::path::PathBuf;
use std::sync::Arc;

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::{
    Batcher, GenerateRequest, McEngine, Metrics, StopCondition,
};
use mc_moe::moe::model::MoeModel;
use mc_moe::moe::qz;
use mc_moe::offload::{self, ExpertCache, ExpertStore, PrefetchMode};
use mc_moe::quant::quantize_rtn;

mod common;
use common::random_model;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("{name}_{}.mcqz", std::process::id()))
}

/// Uniformly 2-bit-quantized model: every expert has identical
/// storage bytes, so budgets translate to exact slot capacities.
fn quantized_model(seed: u64) -> MoeModel {
    let cfg = ModelConfig::test_tiny();
    let mut m = random_model(&cfg, seed);
    for layer in m.layers.iter_mut() {
        for ex in layer.experts.iter_mut() {
            ex.w1 = quantize_rtn(&ex.w1.dequantize(), 2);
            ex.w3 = quantize_rtn(&ex.w3.dequantize(), 2);
            ex.w2 = quantize_rtn(&ex.w2.dequantize(), 2);
        }
    }
    m
}

fn per_expert_bytes(m: &MoeModel) -> usize {
    m.layers[0].experts[0].storage_bytes()
}

fn greedy(prompt: Vec<u32>, max_new: usize) -> GenerateRequest {
    // MaxLen: run the full decode length regardless of EOS, so the
    // cached run exercises sustained churn
    GenerateRequest::greedy(prompt, max_new).with_stop(StopCondition::MaxLen)
}

#[test]
fn engine_greedy_parity_under_budget() {
    let m = quantized_model(21);
    let path = tmp("offload_engine");
    qz::save(&path, &m).unwrap();
    let per = per_expert_bytes(&m);
    let total: usize = m.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();

    let resident = McEngine::new(qz::load(&path).unwrap(), None, None);
    // 50% residency: room for one layer's pinned set plus the
    // prefetched set of the next
    let budget = 4 * per;
    assert!(budget < total, "budget must be under total expert bytes");
    let cached_model =
        offload::load_cached(&path, budget, PrefetchMode::Sync).unwrap();
    let metrics = cached_model.resolver.metrics().unwrap();
    let cached = McEngine::new(cached_model, None, None);
    assert!(Arc::ptr_eq(&metrics, &cached.metrics),
            "engine adopts the cache's metrics");

    let prompts: [&[u32]; 3] = [&[1, 5, 80, 3], &[2, 9, 81, 44, 7], &[1, 30, 3]];
    for prompt in prompts {
        let req = greedy(prompt.to_vec(), 40);
        let want = resident.generate(&req).unwrap();
        let got = cached.generate(&req).unwrap();
        assert_eq!(got.tokens, want.tokens,
                   "budget-capped tokens must be bit-identical");
        assert_eq!(got.finish, want.finish);
    }

    use std::sync::atomic::Ordering::Relaxed;
    assert!(metrics.expert_cache_misses.load(Relaxed) > 0,
            "a 50% budget must demand-load");
    assert!(metrics.expert_cache_evictions.load(Relaxed) > 0,
            "a 50% budget must evict");
    assert!(metrics.expert_cache_hits.load(Relaxed) > 0);
    assert!(metrics.prefetch_hit_rate() > 0.0,
            "the co-activation predictor must land some prefetches \
             ({} issued, {} hit)",
            metrics.expert_prefetch_issued.load(Relaxed),
            metrics.expert_prefetch_hits.load(Relaxed));
    assert!(!metrics.miss_stall_ns.lock().unwrap().is_empty(),
            "miss stalls must be recorded");
    std::fs::remove_file(&path).ok();
}

#[test]
fn fused_batcher_parity_under_budget() {
    let m = quantized_model(22);
    let path = tmp("offload_batcher");
    qz::save(&path, &m).unwrap();
    let per = per_expert_bytes(&m);

    let run = |model: MoeModel, metrics: &Metrics| -> Vec<(u64, Vec<u32>)> {
        let mut b = Batcher::new(Arc::new(model), None, 2);
        let prompts: [&[u32]; 3] =
            [&[1, 5, 80, 3], &[2, 9, 81, 44, 7], &[1, 30, 3]];
        // hold the handles across the run: dropping one cancels it
        let handles: Vec<_> = prompts
            .iter()
            .map(|p| b.submit(greedy(p.to_vec(), 12)))
            .collect();
        let ids: Vec<u64> = handles.iter().map(|h| h.id).collect();
        let done = b.run_to_completion(metrics);
        ids.iter()
            .map(|&id| {
                let c = done.iter().find(|c| c.id == id).unwrap();
                (id, c.tokens.clone())
            })
            .collect()
    };

    let resident_metrics = Metrics::new();
    let want = run(qz::load(&path).unwrap(), &resident_metrics);

    let cached_model =
        offload::load_cached(&path, 4 * per, PrefetchMode::Sync).unwrap();
    let metrics = cached_model.resolver.metrics().unwrap();
    let got = run(cached_model, &metrics);
    assert_eq!(got, want,
               "fused batcher tokens must match fully-resident exactly");

    use std::sync::atomic::Ordering::Relaxed;
    assert!(metrics.expert_cache_misses.load(Relaxed) > 0);
    assert!(metrics.expert_cache_evictions.load(Relaxed) > 0,
            "batch-wide routing under a 50% budget must evict");
    std::fs::remove_file(&path).ok();
}

#[test]
fn pinned_experts_never_evicted_under_pressure() {
    let m = quantized_model(23);
    let path = tmp("offload_pins");
    qz::save(&path, &m).unwrap();
    let per = per_expert_bytes(&m);
    let (_, store) = ExpertStore::open(&path).unwrap();
    let metrics = Arc::new(Metrics::new());
    // budget: two experts
    let cache = ExpertCache::new(Arc::new(store), 2 * per, metrics.clone());

    // pin the whole budget, as a mid-step dispatch would
    let a = cache.get_pinned(0, 0);
    let b = cache.get_pinned(0, 1);
    // pressure: demand + prefetch more experts than the budget holds
    cache.get_pinned(1, 0);
    cache.unpin(1, 0);
    cache.prefetch(1, 1);
    cache.get_pinned(1, 2);
    cache.unpin(1, 2);
    assert!(cache.contains(0, 0) && cache.contains(0, 1),
            "pinned experts must survive every form of pressure");
    use std::sync::atomic::Ordering::Relaxed;
    assert!(metrics.expert_cache_evictions.load(Relaxed) > 0,
            "unpinned slots churned instead");
    // weights stay usable while pinned
    assert!(a.w1.shape().0 > 0 && b.w1.shape().0 > 0);

    // once unpinned, pressure may evict them
    cache.unpin(0, 0);
    cache.unpin(0, 1);
    for e in 0..4 {
        cache.get_pinned(1, e);
        cache.unpin(1, e);
    }
    assert!(cache.bytes_resident() <= 2 * per,
            "with no pins the budget is enforced again");
    std::fs::remove_file(&path).ok();
}
