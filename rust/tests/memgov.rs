//! Integration tests for the memory governor (ISSUE 9, DESIGN.md §8):
//! the accounting property (reservations never exceed the ceiling and
//! rebalance exactly after retirement), CoW prefix-sharing parity
//! (bit-identical tokens with and without the governor), the pressure
//! ladder with hysteresis, injected `oom=P` refusals, and the KV
//! down-quantization retrieval sweep behind the rung-3 action.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, Mutex};

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::{
    Batcher, DecodeSession, GenerateRequest, MemGovConfig, MemReservation,
    MemoryGovernor, Metrics, StopCondition,
};
use mc_moe::moe::model::MoeModel;
use mc_moe::util::faults::{self, FaultPlan};
use mc_moe::util::rng::Rng;

mod common;
use common::random_model;

/// `faults::install` swaps a process-global plan: every test that
/// reserves bytes serializes here (and neutralizes any `MC_FAULTS`
/// environment plan) so an `oom=P` draw can never leak across tests.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn fault_free() -> std::sync::MutexGuard<'static, ()> {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    faults::install(None);
    guard
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .expect("non-empty logits")
}

#[test]
fn governor_accounting_never_exceeds_ceiling_and_rebalances() {
    let _fl = fault_free();
    let budget = 1u64 << 20;
    let gov = MemoryGovernor::new(
        MemGovConfig { budget_bytes: budget, ..MemGovConfig::default() },
        &ModelConfig::test_tiny(),
        4096,
        Arc::new(Metrics::new()),
    );
    let baseline = gov.baseline_bytes();
    assert_eq!(gov.bytes_reserved(), baseline);

    // pseudo-random reserve / shrink / release storm: the invariant is
    // checked after every single transition, not just at the end
    let mut rng = Rng::new(7);
    let mut held: Vec<MemReservation> = Vec::new();
    let mut granted = 0u32;
    let mut refused = 0u32;
    for _ in 0..2000 {
        if rng.below(3) == 0 && !held.is_empty() {
            let i = rng.below(held.len());
            if rng.below(4) == 0 {
                // partial early return (the rung-3 shrink path), then
                // the remainder releases on drop
                let half = held[i].bytes() / 2;
                held[i].shrink(half);
            }
            held.swap_remove(i);
        } else {
            let bytes = 1 + rng.below(96 << 10) as u64;
            match gov.try_reserve(bytes) {
                Some(r) => {
                    granted += 1;
                    held.push(r);
                }
                None => refused += 1,
            }
        }
        assert!(
            gov.bytes_reserved() <= budget,
            "reserved {} exceeds the {budget}-byte ceiling",
            gov.bytes_reserved()
        );
    }
    assert!(granted > 0, "storm too strict: nothing was ever admitted");
    assert!(refused > 0, "storm too lax: the ceiling was never hit");
    held.clear();
    assert_eq!(
        gov.bytes_reserved(),
        baseline,
        "every session byte must return once all reservations retire"
    );
}

fn batcher_run(
    model: &Arc<MoeModel>,
    gov: Option<&Arc<MemoryGovernor>>,
    prompt: &[u32],
) -> Vec<u32> {
    let metrics = Metrics::new();
    let mut b = Batcher::new(model.clone(), None, 1);
    if let Some(g) = gov {
        b.set_governor(g.clone());
    }
    let h = b.submit(
        GenerateRequest::greedy(prompt.to_vec(), 8)
            .with_stop(StopCondition::MaxLen),
    );
    b.run_to_completion(&metrics);
    h.wait().expect("completion").tokens
}

#[test]
fn prefix_sharing_emits_bit_identical_tokens() {
    let _fl = fault_free();
    let cfg = ModelConfig::test_tiny();
    let model = Arc::new(random_model(&cfg, 21));
    // head (prompt minus the last token) is 11 rows >= min_prefix_rows
    let prompt: Vec<u32> = vec![4, 9, 17, 3, 88, 41, 7, 7, 120, 5, 66, 13];

    let base_a = batcher_run(&model, None, &prompt);
    let base_b = batcher_run(&model, None, &prompt);
    assert_eq!(base_a, base_b, "ungoverned decode must be deterministic");

    // derived-default budget: unconstrained, so no degradation rung
    // ever fires and parity is exact
    let metrics = Arc::new(Metrics::new());
    let gov = MemoryGovernor::for_model(&cfg, None, 1, None, metrics.clone());
    let gov_a = batcher_run(&model, Some(&gov), &prompt); // publishes head
    let gov_b = batcher_run(&model, Some(&gov), &prompt); // rides the prefix

    assert_eq!(gov_a, base_a, "governed (publisher) run must be bit-identical");
    assert_eq!(gov_b, base_a, "prefix-sharing run must be bit-identical");
    assert!(
        metrics.kv_prefix_published.load(Relaxed) >= 1,
        "first governed run must publish its prompt head"
    );
    assert!(
        metrics.kv_prefix_hits.load(Relaxed) >= 1,
        "second governed run must attach the shared prefix"
    );
    assert_eq!(gov.rung(), 0, "derived default budget never degrades");

    // both sessions retired: only the published prefix still holds
    // bytes, and evicting it re-balances to the static baseline
    assert_eq!(gov.prefix_count(), 1);
    assert_eq!(gov.evict_idle_prefixes(), 1);
    assert_eq!(
        gov.bytes_reserved(),
        gov.baseline_bytes(),
        "accounting must return to baseline after sessions + prefix retire"
    );
}

#[test]
fn pressure_ladder_engages_and_releases_with_hysteresis() {
    let _fl = fault_free();
    let cfg = ModelConfig::test_tiny();
    let model = random_model(&cfg, 5);
    let metrics = Arc::new(Metrics::new());
    let gov = MemoryGovernor::new(
        MemGovConfig { budget_bytes: 1000, ..MemGovConfig::default() },
        &cfg,
        0,
        metrics.clone(),
    );

    assert_eq!(gov.tick(&model), 0);
    let r1 = gov.try_reserve(500).unwrap(); // 0.50 -> pause prefetch
    assert_eq!(gov.tick(&model), 1);
    let r2 = gov.try_reserve(200).unwrap(); // 0.70 -> shrink expert budget
    assert_eq!(gov.tick(&model), 2);
    let r3 = gov.try_reserve(150).unwrap(); // 0.85 -> evict/down-quantize
    assert_eq!(gov.tick(&model), 3);
    let r4 = gov.try_reserve(100).unwrap(); // 0.95 -> defer Low sessions
    assert_eq!(gov.tick(&model), 4);

    assert_eq!(metrics.mem_prefetch_pauses.load(Relaxed), 1);
    assert_eq!(metrics.mem_budget_shrinks.load(Relaxed), 1);
    assert_eq!(metrics.mem_pressure_rung.load(Relaxed), 4);

    // hysteresis on the way down: at 0.85 rung 4 disengages (below
    // 0.95 - 0.05) but rung 3 holds (0.85 is not below 0.85 - 0.05)
    drop(r4);
    assert_eq!(gov.tick(&model), 3);
    drop(r3); // 0.70: rung 3 releases, rung 2 holds
    assert_eq!(gov.tick(&model), 2);
    drop(r2); // 0.50: rung 2 releases, rung 1 holds
    assert_eq!(gov.tick(&model), 1);
    drop(r1); // 0.0: fully recovered
    assert_eq!(gov.tick(&model), 0);
    assert_eq!(metrics.mem_pressure_rung.load(Relaxed), 0);
    // recovery reverses the actions without re-counting engagements
    assert_eq!(metrics.mem_prefetch_pauses.load(Relaxed), 1);
    assert_eq!(metrics.mem_budget_shrinks.load(Relaxed), 1);
}

#[test]
fn injected_oom_refuses_reservation_and_admission() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = ModelConfig::test_tiny();
    let metrics = Arc::new(Metrics::new());
    let gov = MemoryGovernor::new(
        MemGovConfig { budget_bytes: 1 << 30, ..MemGovConfig::default() },
        &cfg,
        0,
        metrics.clone(),
    );

    faults::install(Some(FaultPlan::parse("oom=1.0").unwrap()));
    assert!(gov.try_reserve(16).is_none(), "oom=1.0 must refuse every draw");
    let prompt: Vec<u32> = (1..=12).collect();
    assert!(
        gov.admit_session(&prompt, 4).is_err(),
        "admission inherits the injected refusal"
    );
    assert!(metrics.mem_oom_injected.load(Relaxed) >= 2);
    assert_eq!(metrics.mem_admission_rejected.load(Relaxed), 1);
    assert_eq!(gov.bytes_reserved(), 0, "refusals must not leak bytes");

    faults::install(None);
    let r = gov.try_reserve(16).expect("uninstall restores service");
    assert_eq!(gov.bytes_reserved(), 16);
    drop(r);
    assert_eq!(gov.bytes_reserved(), 0);
    drop(guard);
}

/// Retrieval check behind the rung-3 action (EXPERIMENTS.md): sweep
/// the down-quantize fraction over cold KV pages of a long prompt and
/// measure next-token agreement with the uncompressed session — the
/// random-weights stand-in for needle-in-a-haystack accuracy. The
/// default `downq_frac = 0.5` must keep agreement high; `frac = 0.0`
/// must be bit-exact.
#[test]
fn kv_downquantize_sweep_preserves_retrieval_at_default() {
    let mut cfg = ModelConfig::test_tiny();
    cfg.max_seq = 256;
    // 220 rows -> cold-page cutoff (220 - 16) / 64 = 3 eligible pages,
    // so the sweep is non-degenerate: frac 0.5 quantizes 2, 1.0 all 3
    let prompt: Vec<u32> = (0..220).map(|i| 1 + (i * 7 % 97) as u32).collect();
    const TRIALS: u64 = 12;

    let mut agree = [0u32; 3]; // frac 0.0 / 0.5 / 1.0
    for t in 0..TRIALS {
        let model = Arc::new(random_model(&cfg, 1000 + t));
        let mut base = DecodeSession::new(model.clone(), None);
        base.enable_importance();
        let first = argmax(&base.prefill(&prompt));
        let base_next = argmax(&base.step(first as u32));

        for (slot, frac, want_pages) in
            [(0usize, 0.0f64, 0usize), (1, 0.5, 2), (2, 1.0, 3)]
        {
            let mut s = DecodeSession::new(model.clone(), None);
            s.enable_importance();
            assert_eq!(argmax(&s.prefill(&prompt)), first,
                       "prefill must be deterministic");
            let saved = s.kv_compress(frac, 16);
            assert_eq!(s.quantized_pages(), want_pages,
                       "frac {frac} must touch exactly {want_pages} pages");
            if frac == 0.0 {
                assert_eq!(saved, 0);
            } else {
                assert!(saved > 0, "down-quantizing must free bytes");
            }
            if argmax(&s.step(first as u32)) == base_next {
                agree[slot] += 1;
            }
        }
    }
    assert_eq!(agree[0] as u64, TRIALS, "frac = 0.0 must be bit-exact");
    let acc = |n: u32| n as f64 / TRIALS as f64;
    println!(
        "KV down-quantize sweep over {TRIALS} models: \
         acc(0.0)={:.2} acc(0.5)={:.2} acc(1.0)={:.2}",
        acc(agree[0]), acc(agree[1]), acc(agree[2])
    );
    assert!(
        acc(agree[1]) >= 0.75,
        "default downq_frac=0.5 must preserve next-token retrieval \
         (got {:.2})",
        acc(agree[1])
    );
}
