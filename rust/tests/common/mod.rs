//! Shared integration-test support. The random-model helper lives
//! behind `cfg(test)` in the lib (`moe::model::tests::random_model`),
//! which integration-test crates cannot see; this is the one
//! out-of-crate copy they all share (keep the init recipe in sync
//! with the lib helper).

use mc_moe::config::ModelConfig;
use mc_moe::moe::model::{Expert, Layer, MoeModel};
use mc_moe::quant::QTensor;
use mc_moe::tensor::Mat;
use mc_moe::util::rng::Rng;

pub fn random_model(cfg: &ModelConfig, seed: u64) -> MoeModel {
    let mut rng = Rng::new(seed);
    let d = cfg.d_model;
    let mk = |rng: &mut Rng, r: usize, c: usize| {
        QTensor::F32(Mat::randn(rng, r, c, (r as f32).powf(-0.5)))
    };
    let layers = (0..cfg.n_layers)
        .map(|_| Layer {
            attn_norm: vec![1.0; d],
            ffn_norm: vec![1.0; d],
            gate: Mat::randn(&mut rng, d, cfg.n_experts, (d as f32).powf(-0.5)),
            wq: mk(&mut rng, d, d),
            wk: mk(&mut rng, d, d),
            wv: mk(&mut rng, d, d),
            wo: mk(&mut rng, d, d),
            experts: (0..cfg.n_experts)
                .map(|_| Expert {
                    w1: mk(&mut rng, d, cfg.d_ff),
                    w3: mk(&mut rng, d, cfg.d_ff),
                    w2: mk(&mut rng, cfg.d_ff, d),
                })
                .collect(),
        })
        .collect();
    MoeModel {
        cfg: cfg.clone(),
        tok_emb: Mat::randn(&mut rng, cfg.vocab_size, d, 0.02),
        pos_emb: Mat::randn(&mut rng, cfg.max_seq, d, 0.02),
        final_norm: vec![1.0; d],
        lm_head: Mat::randn(&mut rng, d, cfg.vocab_size, (d as f32).powf(-0.5)),
        layers,
        resolver: mc_moe::offload::resident(),
    }
}
