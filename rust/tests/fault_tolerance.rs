//! End-to-end fault-tolerance tests (DESIGN.md §7): injected faults
//! against real servers, proving each rung of the degradation ladder
//! — worker panics become 500s, total fetch failure degrades dispatch
//! instead of crashing, quarantine expiry restores bit-exact output,
//! and deadlines map to 504 / SSE `error` frames over the wire.
//!
//! Lives in its own integration crate (= its own process) because
//! `faults::install` is process-global: installing a panic plan here
//! cannot perturb the other suites. Tests that install a plan
//! serialize on `FAULT_LOCK`.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use mc_moe::config::ModelConfig;
use mc_moe::coordinator::{
    FinishReason, GenerateRequest, Server, StopCondition,
};
use mc_moe::moe::qz;
use mc_moe::offload::{self, FetchPolicy, PrefetchMode};
use mc_moe::serve::client::{self, GenerateReply};
use mc_moe::serve::{HttpServer, ServeConfig};
use mc_moe::util::faults::{self, FaultPlan};

mod common;
use common::random_model;

/// Generous per-read bound: a wedged stream fails, never hangs.
const T: Duration = Duration::from_secs(120);

/// Serializes tests that install a process-global fault plan.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn fault_guard() -> std::sync::MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn serve(model: mc_moe::moe::model::MoeModel, scfg: ServeConfig)
         -> HttpServer {
    let engine = Server::spawn(Arc::new(model), None, scfg.max_batch);
    HttpServer::bind(engine, scfg).expect("bind 127.0.0.1:0")
}

fn small_serve_cfg() -> ServeConfig {
    ServeConfig {
        port: 0,
        max_conns: 4,
        max_streams_per_tenant: 0,
        shed_queue_depth: 0,
        max_batch: 2,
        ..ServeConfig::default()
    }
}

fn gen_body(prompt: &[u32], max_new: usize, extra: &str) -> Vec<u8> {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"prompt\":[{}],\"max_new_tokens\":{max_new},\
         \"stop\":\"max_len\"{extra}}}",
        toks.join(",")
    )
    .into_bytes()
}

/// A slower model so deadline tests cannot outrace generation.
fn slow_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::test_tiny();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.d_ff = 256;
    cfg.n_layers = 4;
    cfg.max_seq = 256;
    cfg
}

#[test]
fn injected_worker_panic_returns_500_then_recovers() {
    let _g = fault_guard();
    faults::install(Some(FaultPlan::parse("panic=1.0,seed=2").unwrap()));

    let http = serve(random_model(&ModelConfig::test_tiny(), 21),
                     small_serve_cfg());
    let body = gen_body(&[1, 5, 80, 3], 4, ",\"stream\":false");

    // the worker panics at the top of the request; the pool must give
    // the client a clean 500 instead of a dead socket
    let poisoned = client::request(http.addr(), "POST", "/v1/generate",
                                   &[], &body, T)
        .expect("panicking worker still answers");
    assert_eq!(poisoned.status, 500, "{}", poisoned.body_str());
    assert!(poisoned.body_str().contains("internal error"),
            "{}", poisoned.body_str());

    // faults off: the *same worker pool* serves the next request
    faults::install(None);
    let ok = client::request(http.addr(), "POST", "/v1/generate",
                             &[], &body, T).unwrap();
    assert_eq!(ok.status, 200, "{}", ok.body_str());
    assert!(ok.body_str().contains("\"tokens\":["));

    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(http.metrics().panics_recovered.load(Relaxed), 1);

    let report = http.shutdown();
    assert!(report.drained, "panic must not pin the drain");
}

#[test]
fn total_fetch_failure_degrades_then_recovers_bit_exact() {
    let _g = fault_guard();

    let cfg = ModelConfig::test_tiny();
    let prompt = vec![1u32, 5, 80, 3];
    let request = || {
        GenerateRequest::greedy(prompt.clone(), 8)
            .with_stop(StopCondition::MaxLen)
    };

    // ground truth on the fully-resident twin
    let m = random_model(&cfg, 33);
    let path = std::env::temp_dir()
        .join(format!("fault_degrade_{}.mcqz", std::process::id()));
    qz::save(&path, &m).unwrap();
    let expert_bytes: usize = m.layers.iter().flat_map(|l| &l.experts)
        .map(|e| e.storage_bytes()).sum();
    let reference = {
        let engine = Server::spawn(Arc::new(m), None, 1);
        let done = engine.submit(request()).wait().expect("reference run");
        engine.shutdown();
        done.tokens
    };
    assert_eq!(reference.len(), 8);

    // every demand fetch fails: all routed experts quarantine and
    // every dispatch degrades to the residual-only path — yet the
    // request completes instead of crashing or wedging
    faults::install(Some(FaultPlan::parse("io_err=1.0,seed=3").unwrap()));
    let cached = offload::load_cached_with_policy(
        &path, expert_bytes / 2, PrefetchMode::Off,
        FetchPolicy {
            max_retries: 0,
            backoff: Duration::ZERO,
            quarantine: Duration::from_millis(100),
        })
        .unwrap();
    let engine = Server::spawn(Arc::new(cached), None, 1);
    let metrics = engine.metrics.clone();
    let done = engine.submit(request()).wait().expect("degraded run");
    assert_eq!(done.finish, FinishReason::MaxTokens,
               "degraded generation still runs to its token budget");
    assert_eq!(done.tokens.len(), 8);

    use std::sync::atomic::Ordering::Relaxed;
    assert!(metrics.expert_load_failures.load(Relaxed) > 0);
    assert!(metrics.experts_quarantined.load(Relaxed) > 0);
    assert!(metrics.degraded_dispatches.load(Relaxed) > 0,
            "dispatch must have degraded around quarantined experts");

    // faults cleared + quarantine lapsed: the same server recovers to
    // bit-exact agreement with the resident model, no restart
    faults::install(None);
    std::thread::sleep(Duration::from_millis(150));
    let healed = engine.submit(request()).wait().expect("recovered run");
    assert_eq!(healed.tokens, reference,
               "post-quarantine output must be bit-exact");
    engine.shutdown();
    std::fs::remove_file(&path).ok();
}

/// The flight recorder (DESIGN.md §9) must leave a post-mortem on the
/// two fault paths this suite injects: a recovered worker panic and a
/// blown deadline each auto-dump a Chrome trace into the configured
/// dump directory.
#[test]
fn flight_recorder_dumps_on_panic_and_blown_deadline() {
    let _g = fault_guard();
    let dir = std::env::temp_dir()
        .join(format!("mc_trace_dumps_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    mc_moe::obs::set_dump_dir(Some(dir.clone()));
    mc_moe::obs::set_enabled(true);

    let dumps_named = |prefix: &str| -> Vec<std::path::PathBuf> {
        std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(prefix))
            })
            .collect()
    };

    // slow model so the 1ms deadline below reliably blows mid-decode
    let http = serve(random_model(&slow_cfg(), 12), small_serve_cfg());
    let prompt = [1u32, 5, 80, 3];

    // -- injected worker panic -> mc-trace-panic-*.json --------------
    faults::install(Some(FaultPlan::parse("panic=1.0,seed=2").unwrap()));
    let resp = client::request(http.addr(), "POST", "/v1/generate", &[],
                               &gen_body(&prompt, 4, ",\"stream\":false"), T)
        .expect("panicking worker still answers");
    assert_eq!(resp.status, 500, "{}", resp.body_str());
    let panics = dumps_named("mc-trace-panic-");
    assert_eq!(panics.len(), 1, "one panic, one dump: {panics:?}");
    let body = std::fs::read_to_string(&panics[0]).unwrap();
    assert!(body.contains("\"traceEvents\""), "not Chrome JSON: {body}");
    assert!(body.contains("panic_recovered"),
            "dump must include the panic marker event");

    // -- blown deadline -> mc-trace-deadline-*.json ------------------
    faults::install(Some(FaultPlan::default()));
    let resp = client::request(
        http.addr(), "POST", "/v1/generate", &[],
        &gen_body(&prompt, 240, ",\"timeout_ms\":1,\"stream\":false"), T)
        .expect("deadline request answered");
    assert_eq!(resp.status, 504, "{}", resp.body_str());
    let deadlines = dumps_named("mc-trace-deadline-");
    assert!(!deadlines.is_empty(), "blown deadline must dump a trace");
    let body = std::fs::read_to_string(&deadlines[0]).unwrap();
    assert!(body.contains("\"traceEvents\""), "not Chrome JSON: {body}");

    // disabled tracing dumps nothing — the production default
    mc_moe::obs::set_enabled(false);
    assert!(mc_moe::obs::dump_now("manual").is_none(),
            "dump_now must be a no-op while tracing is off");
    assert!(dumps_named("mc-trace-manual-").is_empty());

    faults::install(None);
    mc_moe::obs::set_dump_dir(None);
    mc_moe::obs::clear();
    let report = http.shutdown();
    assert!(report.drained);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn timeout_ms_maps_to_504_and_sse_error() {
    // deadlines need no fault plan, but the guard still serializes us
    // behind the tests that install one (a concurrent panic=1.0 plan
    // would poison these requests), and the all-zero install shields
    // the timing from any ambient MC_FAULTS delay spec
    let _g = fault_guard();
    faults::install(Some(FaultPlan::default()));
    let http = serve(random_model(&slow_cfg(), 12), small_serve_cfg());
    let prompt = [1u32, 5, 80, 3];

    // non-streaming: a 1ms budget against a 240-token request can
    // only end one way — 504, with the partial completion attached
    let resp = match client::open_generate(
        http.addr(),
        &gen_body(&prompt, 240, ",\"timeout_ms\":1,\"stream\":false"),
        &[], T)
        .expect("request reached the server")
    {
        GenerateReply::Response(r) => r,
        GenerateReply::Stream(_) => panic!("stream:false must not stream"),
    };
    assert_eq!(resp.status, 504, "{}", resp.body_str());
    assert!(resp.body_str().contains("\"finish\":\"deadline_exceeded\""),
            "{}", resp.body_str());
    assert!(resp.body_str().contains("\"tokens\":["),
            "504 still carries the partial completion");

    // streaming: the deadline surfaces as a terminal SSE `error`
    // frame, never a silently cut stream
    let mut stream = match client::open_generate(
        http.addr(), &gen_body(&prompt, 240, ",\"timeout_ms\":1"), &[], T)
        .expect("request reached the server")
    {
        GenerateReply::Stream(s) => s,
        GenerateReply::Response(r) => {
            panic!("expected SSE, got {} {}", r.status, r.body_str())
        }
    };
    let terminal = loop {
        match stream.next_event().expect("sse read") {
            Some(ev) if ev.name == "token" => continue,
            Some(ev) => break ev,
            None => panic!("stream closed without a terminal frame"),
        }
    };
    assert_eq!(terminal.name, "error", "data: {}", terminal.data);
    assert!(terminal.data.contains("\"finish\":\"deadline_exceeded\""),
            "{}", terminal.data);

    use std::sync::atomic::Ordering::Relaxed;
    assert!(http.metrics().deadline_exceeded.load(Relaxed) >= 2);

    let report = http.shutdown();
    assert!(report.drained, "expired requests must not pin the drain");
    faults::install(None);
}
