//! Kernel parity suite (ISSUE 4): the tiled GEMM, the fused packed
//! decode kernel, and every pool-parallel path must agree with their
//! reference implementations —
//!
//!   * tiled `matmul_into` vs the kept naive scalar-ikj reference, at
//!     shapes that exercise every remainder path (rows % 4, K % 4);
//!   * fused packed small-M decode vs `dequantize() + dense matmul`
//!     for bits ∈ {2, 3, 4} at group/word edge cases (K < GROUP_SIZE,
//!     K where 3-bit words straddle group boundaries);
//!   * pool-vs-serial **bit-exactness** for GEMM column strips,
//!     attention head fan-out, and expert dispatch (the pool
//!     partitions disjoint writes, so results must be identical to
//!     the last bit, not just within tolerance);
//!   * every compiled SIMD backend (`kernels::available()`) vs the
//!     scalar reference, through the `*_ops` entry points, at ragged
//!     shapes and every packed bit-width. Tolerances are per stage
//!     (DESIGN.md §4): FMA accumulation stages (GEMM, packed
//!     word-acc, attention scores) carry a ~1e-4 relative bound;
//!     scale/zero application, dequant rows, binary masked-adds and
//!     softmax replicate the scalar operation sequence exactly, so
//!     those paths are asserted (effectively) bit-exact.

use mc_moe::kernels;
use mc_moe::moe::exec::attention::{
    causal_attention_into, causal_attention_into_ops, AttnScratch,
};
use mc_moe::moe::exec::dispatch::{
    dispatch_experts, scatter, DispatchMode, ExpertsRef,
};
use mc_moe::moe::model::Expert;
use mc_moe::quant::linear::quantize_groupwise;
use mc_moe::quant::qmatmul::QmScratch;
use mc_moe::quant::{binary::binarize, qmatmul, QTensor};
use mc_moe::tensor::{
    matmul_into_naive, matmul_into_ops, matmul_into_with, softmax_rows_ops,
    Mat,
};
use mc_moe::util::pool::WorkerPool;
use mc_moe::util::rng::Rng;

fn assert_close(a: &Mat, b: &Mat, tol: f32, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn tiled_gemm_matches_naive_at_odd_shapes() {
    let mut rng = Rng::new(0);
    // every (rows mod 4, K mod 4) remainder class plus tall/wide
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (1, 63, 17),
        (2, 30, 5),
        (3, 33, 129),
        (4, 64, 64),
        (5, 13, 7),
        (6, 130, 31),
        (7, 8, 256),
        (8, 127, 65),
        (13, 66, 19),
    ] {
        let x = Mat::randn(&mut rng, m, k, 1.0);
        let w = Mat::randn(&mut rng, k, n, 1.0);
        let mut tiled = Mat::zeros(m, n);
        matmul_into_with(&x, &w, &mut tiled, None);
        let mut naive = Mat::zeros(m, n);
        matmul_into_naive(&x, &w, &mut naive);
        assert_close(&tiled, &naive, 1e-4, &format!("gemm {m}x{k}x{n}"));
    }
}

#[test]
fn tiled_gemm_handles_sparse_activations() {
    // the naive kernel skips zero activations; the tiled kernel must
    // produce the same result without the branch
    let mut rng = Rng::new(1);
    let mut x = Mat::randn(&mut rng, 6, 40, 1.0);
    for (i, v) in x.data.iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0;
        }
    }
    let w = Mat::randn(&mut rng, 40, 24, 1.0);
    let mut tiled = Mat::zeros(6, 24);
    matmul_into_with(&x, &w, &mut tiled, None);
    let mut naive = Mat::zeros(6, 24);
    matmul_into_naive(&x, &w, &mut naive);
    assert_close(&tiled, &naive, 1e-4, "sparse gemm");
}

#[test]
fn pooled_gemm_strips_bit_match_serial() {
    let mut rng = Rng::new(2);
    let pool = WorkerPool::global();
    for &(m, k, n) in &[(1usize, 64usize, 200usize), (9, 33, 128), (64, 64, 300)] {
        let x = Mat::randn(&mut rng, m, k, 1.0);
        let w = Mat::randn(&mut rng, k, n, 1.0);
        let mut serial = Mat::zeros(m, n);
        matmul_into_with(&x, &w, &mut serial, None);
        let mut pooled = Mat::zeros(m, n);
        matmul_into_with(&x, &w, &mut pooled, Some(pool));
        assert_eq!(serial.data, pooled.data,
                   "gemm strips must be bit-exact ({m}x{k}x{n})");
    }
}

#[test]
fn fused_packed_decode_matches_dequant_reference() {
    let mut rng = Rng::new(3);
    // K values exercising the word/group edge cases:
    //  * 30, 50: K < GROUP_SIZE (group == K), partial final word for
    //    every bit-width (30 % 16, 50 % 10, 30 % 8 all nonzero)
    //  * 64, 128: group-aligned
    //  * 192: 3-bit words (10 vals) straddle the group-64 boundaries
    for &k in &[30usize, 50, 64, 128, 192] {
        for &bits in &[2usize, 3, 4] {
            let w = Mat::randn(&mut rng, k, 19, 1.0);
            let t = quantize_groupwise(&w, bits);
            let dense = t.dequantize();
            for m in [1usize, 2, 4] {
                let x = Mat::randn(&mut rng, m, k, 1.0);
                let fused = qmatmul::packed_matmul(&x, &t);
                let reference = x.matmul(&dense);
                assert_close(&fused, &reference, 2e-4,
                             &format!("packed k={k} bits={bits} m={m}"));
            }
            // large-M path at the same K edge cases
            let x = Mat::randn(&mut rng, 9, k, 1.0);
            assert_close(&qmatmul::packed_matmul(&x, &t), &x.matmul(&dense),
                         2e-4, &format!("packed large-M k={k} bits={bits}"));
        }
        // binary word unroll at the same K edge cases
        let w = Mat::randn(&mut rng, k, 13, 1.0);
        let b = binarize(&w, false);
        let x = Mat::randn(&mut rng, 3, k, 1.0);
        assert_close(&qmatmul::binary_matmul(&x, &b),
                     &x.matmul(&b.dequantize()), 2e-4,
                     &format!("binary k={k}"));
    }
}

#[test]
fn pooled_attention_heads_bit_match_serial() {
    let mut rng = Rng::new(4);
    let (s, d, nh) = (80, 64, 8);
    let q = Mat::randn(&mut rng, s, d, 1.0);
    let k = Mat::randn(&mut rng, s, d, 1.0);
    let v = Mat::randn(&mut rng, s, d, 1.0);
    let mut scratch = AttnScratch::new();
    let mut serial = Mat::zeros(0, 0);
    causal_attention_into(&q, &k, &v, s, nh, false, None, &mut scratch,
                          &mut serial);
    let mut pooled = Mat::zeros(0, 0);
    causal_attention_into(&q, &k, &v, s, nh, false,
                          Some(WorkerPool::global()), &mut scratch,
                          &mut pooled);
    assert_eq!(serial.data, pooled.data, "attention heads must be bit-exact");
}

#[test]
fn pooled_dispatch_bit_matches_serial_and_spawn() {
    let mut rng = Rng::new(5);
    let (rows, d, d_ff, ne, top_k) = (48, 16, 32, 6, 2);
    let experts: Vec<Expert> = (0..ne)
        .map(|_| Expert {
            w1: QTensor::F32(Mat::randn(&mut rng, d, d_ff, 0.1)),
            w3: QTensor::F32(Mat::randn(&mut rng, d, d_ff, 0.1)),
            w2: QTensor::F32(Mat::randn(&mut rng, d_ff, d, 0.1)),
        })
        .collect();
    let h = Mat::randn(&mut rng, rows, d, 1.0);
    let topk: Vec<Vec<(usize, f32)>> = (0..rows)
        .map(|t| {
            (0..top_k)
                .map(|j| ((t + j) % ne, 1.0 / top_k as f32))
                .collect()
        })
        .collect();
    let y_serial = scatter(
        &dispatch_experts(&h, &topk, ExpertsRef::resident(&experts), None, DispatchMode::Serial),
        rows, d,
    );
    for mode in [DispatchMode::Threaded, DispatchMode::SpawnScope,
                 DispatchMode::Auto] {
        let y = scatter(&dispatch_experts(&h, &topk, ExpertsRef::resident(&experts), None, mode),
                        rows, d);
        assert_eq!(y_serial.data, y.data, "{mode:?} must be bit-exact");
    }
}

#[test]
fn quantized_expert_dispatch_pool_parity() {
    // pool-vs-serial bit-exactness must also hold when experts run
    // the packed kernels (2/3-bit + binary mix)
    let mut rng = Rng::new(6);
    let (rows, d, d_ff, ne, top_k) = (24, 64, 64, 4, 2);
    let experts: Vec<Expert> = (0..ne)
        .map(|e| {
            let w1 = Mat::randn(&mut rng, d, d_ff, 0.1);
            let w3 = Mat::randn(&mut rng, d, d_ff, 0.1);
            let w2 = Mat::randn(&mut rng, d_ff, d, 0.1);
            match e % 3 {
                0 => Expert {
                    w1: QTensor::Packed(quantize_groupwise(&w1, 2)),
                    w3: QTensor::Packed(quantize_groupwise(&w3, 3)),
                    w2: QTensor::Packed(quantize_groupwise(&w2, 4)),
                },
                1 => Expert {
                    w1: QTensor::Binary(binarize(&w1, false)),
                    w3: QTensor::F32(w3),
                    w2: QTensor::Packed(quantize_groupwise(&w2, 3)),
                },
                _ => Expert {
                    w1: QTensor::F32(w1),
                    w3: QTensor::F32(w3),
                    w2: QTensor::F32(w2),
                },
            }
        })
        .collect();
    let h = Mat::randn(&mut rng, rows, d, 1.0);
    let topk: Vec<Vec<(usize, f32)>> = (0..rows)
        .map(|t| {
            (0..top_k)
                .map(|j| ((t + j) % ne, 1.0 / top_k as f32))
                .collect()
        })
        .collect();
    let y_serial = scatter(
        &dispatch_experts(&h, &topk, ExpertsRef::resident(&experts), None, DispatchMode::Serial),
        rows, d,
    );
    let y_pool = scatter(
        &dispatch_experts(&h, &topk, ExpertsRef::resident(&experts), None, DispatchMode::Threaded),
        rows, d,
    );
    assert_eq!(y_serial.data, y_pool.data,
               "quantized dispatch must be bit-exact under the pool");
}

// ---- cross-ISA backend parity (kernels::available() vs scalar) ----

/// Non-scalar tables compiled for this target AND runnable on this
/// CPU. Empty on a machine with no SIMD — every test below then
/// degenerates to a no-op rather than a false pass/fail.
fn simd_backends() -> Vec<&'static kernels::KernelOps> {
    kernels::available()
        .into_iter()
        .filter(|o| o.isa != kernels::Isa::Scalar)
        .collect()
}

#[test]
fn every_backend_matches_scalar_gemm() {
    let mut rng = Rng::new(10);
    let scalar = kernels::table_for(kernels::Isa::Scalar).unwrap();
    // ragged shapes: every lane-remainder class for 8- and 16-wide
    // ISAs (n mod 16 ∈ {1, 5, 7, 8, 15}), plus odd-K tails via k=13/33
    for &(m, k, n) in &[
        (1usize, 13usize, 1usize),
        (2, 33, 5),
        (3, 64, 23),
        (5, 30, 40),
        (8, 127, 65),
        (13, 66, 79),
    ] {
        let x = Mat::randn(&mut rng, m, k, 1.0);
        let w = Mat::randn(&mut rng, k, n, 1.0);
        let mut reference = Mat::zeros(m, n);
        matmul_into_ops(&x, &w, &mut reference, None, scalar);
        for ops in simd_backends() {
            let mut got = Mat::zeros(m, n);
            matmul_into_ops(&x, &w, &mut got, None, ops);
            // FMA accumulation stage: documented ~1e-4 relative bound
            assert_close(&got, &reference, 1e-4,
                         &format!("{} gemm {m}x{k}x{n}", ops.isa.name()));
        }
    }
}

#[test]
fn every_backend_matches_scalar_packed_all_bit_widths() {
    let mut rng = Rng::new(11);
    let scalar = kernels::table_for(kernels::Isa::Scalar).unwrap();
    // same K edge cases as the fused-vs-dequant test: partial words,
    // group == K, and 3-bit words straddling group boundaries
    for &k in &[30usize, 50, 64, 128, 192] {
        for &bits in &[2usize, 3, 4] {
            let w = Mat::randn(&mut rng, k, 19, 1.0);
            let t = quantize_groupwise(&w, bits);
            // m ∈ {1, 4}: small-M fused kernel; m = 9: large-M
            // dequant-row kernel
            for m in [1usize, 4, 9] {
                let x = Mat::randn(&mut rng, m, k, 1.0);
                let mut qs = QmScratch::new();
                let mut reference = Mat::zeros(0, 0);
                qmatmul::packed_matmul_into_ops(&x, &t, &mut reference,
                                                &mut qs, scalar);
                for ops in simd_backends() {
                    let mut got = Mat::zeros(0, 0);
                    qmatmul::packed_matmul_into_ops(&x, &t, &mut got,
                                                    &mut qs, ops);
                    assert_close(&got, &reference, 1e-4,
                                 &format!("{} packed k={k} bits={bits} m={m}",
                                          ops.isa.name()));
                }
            }
        }
    }
}

#[test]
fn every_backend_matches_scalar_binary() {
    let mut rng = Rng::new(12);
    let scalar = kernels::table_for(kernels::Isa::Scalar).unwrap();
    for &k in &[30usize, 50, 64, 128, 192] {
        let w = Mat::randn(&mut rng, k, 21, 1.0);
        let b = binarize(&w, false);
        for m in [1usize, 3, 9] {
            let x = Mat::randn(&mut rng, m, k, 1.0);
            let mut qs = QmScratch::new();
            let mut reference = Mat::zeros(0, 0);
            qmatmul::binary_matmul_into_ops(&x, &b, &mut reference, &mut qs,
                                            scalar);
            for ops in simd_backends() {
                let mut got = Mat::zeros(0, 0);
                qmatmul::binary_matmul_into_ops(&x, &b, &mut got, &mut qs,
                                                ops);
                // masked-add + exact scale application: per-column add
                // order matches scalar, so effectively exact (1e-6
                // leaves headroom for nothing but rounding-mode quirks)
                assert_close(&got, &reference, 1e-6,
                             &format!("{} binary k={k} m={m}",
                                      ops.isa.name()));
            }
        }
    }
}

#[test]
fn softmax_is_bit_identical_across_backends() {
    let mut rng = Rng::new(13);
    let scalar = kernels::table_for(kernels::Isa::Scalar).unwrap();
    for &(rows, cols) in &[(1usize, 7usize), (3, 33), (8, 127)] {
        let src = Mat::randn(&mut rng, rows, cols, 3.0);
        let mut reference = src.clone();
        softmax_rows_ops(&mut reference, scalar);
        for ops in simd_backends() {
            let mut got = src.clone();
            softmax_rows_ops(&mut got, ops);
            // vmax and vscale are exact operations: identical input
            // must produce identical output to the last bit
            assert_eq!(got.data, reference.data,
                       "{} softmax {rows}x{cols}", ops.isa.name());
        }
    }
}

#[test]
fn every_backend_matches_scalar_attention() {
    let mut rng = Rng::new(14);
    let scalar = kernels::table_for(kernels::Isa::Scalar).unwrap();
    // full-sequence and KV-append windows, ragged head dims
    for &(s, klen, d, nh) in &[(9usize, 9usize, 24usize, 2usize),
                               (1, 17, 40, 4), (5, 12, 64, 8)] {
        let q = Mat::randn(&mut rng, s, d, 1.0);
        let k = Mat::randn(&mut rng, klen, d, 1.0);
        let v = Mat::randn(&mut rng, klen, d, 1.0);
        let mut scratch = AttnScratch::new();
        let mut reference = Mat::zeros(0, 0);
        causal_attention_into_ops(&q, &k, &v, klen, nh, false, None,
                                  &mut scratch, &mut reference, scalar);
        for ops in simd_backends() {
            let mut got = Mat::zeros(0, 0);
            causal_attention_into_ops(&q, &k, &v, klen, nh, false, None,
                                      &mut scratch, &mut got, ops);
            // scores accumulate through FMA axpy; softmax + AV stay
            // within the same documented bound
            assert_close(&got, &reference, 1e-4,
                         &format!("{} attention s={s} klen={klen} d={d}",
                                  ops.isa.name()));
        }
    }
}

#[test]
fn kernel_facing_buffers_are_64_byte_aligned() {
    let mut rng = Rng::new(15);
    let m = Mat::randn(&mut rng, 7, 13, 1.0);
    assert_eq!(m.data.as_ptr() as usize % 64, 0, "Mat backing");
    let t = quantize_groupwise(&Mat::randn(&mut rng, 64, 9, 1.0), 3);
    assert_eq!(t.qweight.as_ptr() as usize % 64, 0, "qweight");
    assert_eq!(t.scales.as_ptr() as usize % 64, 0, "scales");
    assert_eq!(t.zeros.as_ptr() as usize % 64, 0, "zeros");
    let b = binarize(&Mat::randn(&mut rng, 96, 5, 1.0), false);
    assert_eq!(b.packed.as_ptr() as usize % 64, 0, "binary packed");
    assert_eq!(b.scales.as_ptr() as usize % 64, 0, "binary scales");
}
