"""Canonical model / tokenizer / packing configuration.

This file is the single source of truth shared (by value, via
``artifacts/config.json``) between the python build path (L1 kernels,
L2 model, trainer, AOT export) and the rust runtime (L3).  The rust side
re-implements the same constants in ``rust/src/config.rs``; the pytest
suite and ``cargo test`` both assert against ``config.json`` so drift is
caught at build time.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Tokenizer spec (fixed 256-symbol vocabulary, shared with rust/src/data)
# ---------------------------------------------------------------------------

VOCAB_SIZE = 256

PAD, BOS, EOS, SEP, QRY = 0, 1, 2, 3, 4
# 8 task-tag tokens identify which grammar generated a sequence.
TASK_BASE = 5            # tasks 0..7 -> tokens 5..12
NUM_BASE = 16            # number tokens 0..63  -> 16..79
NUM_COUNT = 64
SYM_BASE = 80            # symbol alphabet a0..a63 -> 80..143
SYM_COUNT = 64
TXT_BASE = 144           # zipfian "text" word tokens -> 144..255
TXT_COUNT = 112

TASK_NAMES = [
    "copy",       # analogue of PIQA        : surface fidelity
    "reverse",    # analogue of ARC-e       : simple transform
    "sortsym",    # analogue of ARC-c       : harder transform
    "modadd",     # analogue of MathQA      : arithmetic
    "recall",     # analogue of BoolQ       : key-value retrieval
    "majority",   # analogue of HellaSwag   : aggregate statistics
    "counting",   # analogue of Winogrande  : counting/binding
    "induction",  # analogue of MMLU        : in-context induction
]

# ---------------------------------------------------------------------------
# Quantized-weight packing spec (must match rust/src/quant/pack.rs)
# ---------------------------------------------------------------------------

GROUP_SIZE = 64  # quantization group along the K (input) dimension

# values packed per little-endian u32 word, by bit-width
VALS_PER_WORD = {2: 16, 3: 10, 4: 8}
# 1-bit weights: 32 rows per word, column-major bit packing + per-column scale


@dataclass
class ModelConfig:
    """Mixtral-style decoder-only MoE transformer configuration."""

    name: str = "tiny"
    vocab_size: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256          # per-expert hidden dim (SwiGLU)
    n_experts: int = 8
    top_k: int = 2
    max_seq: int = 256
    # serving tile sizes baked into the AOT component executables
    prefill_tile: int = 128  # token-batch tile for expert/gate executables
    # training hyper-parameters (build-time only)
    train_steps: int = 600
    train_batch: int = 16
    train_seq: int = 128
    lr: float = 3e-3
    seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, e, v, s = self.d_model, self.d_ff, self.n_experts, self.vocab_size, self.max_seq
        emb = v * d + s * d
        per_layer = 4 * d * d + 2 * d + d * e + e * 3 * d * f
        return emb + self.n_layers * per_layer + d + d * v

    def expert_param_count(self) -> int:
        return self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @staticmethod
    def from_json(text: str) -> "ModelConfig":
        return ModelConfig(**json.loads(text))


def tiny() -> ModelConfig:
    """Default build config: trains in ~2 min on CPU, ~3.5M params."""
    return ModelConfig()


def small() -> ModelConfig:
    """Mid-size config for ablations (~14M params)."""
    return ModelConfig(
        name="small", d_model=192, n_layers=6, n_heads=6, d_ff=384,
        train_steps=1600, train_batch=24,
    )


def e2e() -> ModelConfig:
    """~100M-param config for the end-to-end example (EXPERIMENTS.md §E2E)."""
    return ModelConfig(
        name="e2e", d_model=512, n_layers=8, n_heads=8, d_ff=1024,
        max_seq=512, train_seq=256, train_batch=8, train_steps=300,
        lr=1e-3,
    )


CONFIGS = {"tiny": tiny, "small": small, "e2e": e2e}


def get(name: str) -> ModelConfig:
    return CONFIGS[name]()
