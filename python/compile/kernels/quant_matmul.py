"""L1 Pallas kernel: fused unpack -> dequantize -> matmul for 2/3/4-bit
group-wise quantized weights (packing spec: kernels/packing.py).

TPU mapping (the HQQ-CUDA-kernel analogue, DESIGN.md §Hardware-
Adaptation): the grid tiles the output columns N.  Each invocation
streams one packed-weight column tile (u32 words — 16x/10x/8x smaller
than f32) HBM->VMEM, unpacks with vectorized shift/mask on the VPU,
applies the per-group scale/zero broadcast, and feeds the dequantized
tile straight to the MXU dot.  The f32 weight tile exists only in VMEM
scratch — never materialized in HBM, which is where the memory saving
comes from.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import GROUP_SIZE, VALS_PER_WORD


def _quant_matmul_kernel(x_ref, qw_ref, s_ref, z_ref, y_ref, *, bits, k):
    vpw = VALS_PER_WORD[bits]
    mask = jnp.uint32(2**bits - 1)
    qw = qw_ref[...]                                   # [K_words, BN] u32
    fields = [((qw >> jnp.uint32(i * bits)) & mask).astype(jnp.float32)
              for i in range(vpw)]                     # VPU shift/mask
    q = jnp.stack(fields, axis=1).reshape(qw.shape[0] * vpw, -1)[:k]
    g = k // GROUP_SIZE
    qg = q.reshape(g, GROUP_SIZE, -1)
    w = (qg - z_ref[...][:, None, :]) * s_ref[...][:, None, :]
    w = w.reshape(k, -1)                               # VMEM-only f32 tile
    y_ref[...] = jnp.dot(x_ref[...], w)                # MXU


def quant_matmul(x, qweight, scales, zeros, bits: int, block_n: int = 128):
    """Pallas twin of ref.quant_matmul_ref; x[M,K] @ deq(qw)[K,N] -> [M,N]."""
    m, k = x.shape
    k_words, n = qweight.shape
    g = k // GROUP_SIZE
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    kern = functools.partial(_quant_matmul_kernel, bits=bits, k=k)
    return pl.pallas_call(
        kern,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k_words, bn), lambda j: (0, j)),
            pl.BlockSpec((g, bn), lambda j: (0, j)),
            pl.BlockSpec((g, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, qweight, scales, zeros)
