"""Pure-jnp oracle implementations for every Pallas kernel.

These are the correctness ground truth: pytest asserts each Pallas
kernel (interpret=True) against its ref counterpart across shape/dtype
sweeps (hypothesis), and the L2 model can be built entirely from refs
(``use_kernels=False``) — the two paths must produce identical logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import GROUP_SIZE, VALS_PER_WORD


def silu(x):
    return x / (1.0 + jnp.exp(-x))


def rmsnorm_ref(x, weight, eps: float = 1e-5):
    """RMSNorm over the last dim."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * weight


def attention_ref(x, wq, wk, wv, wo, n_heads: int, mask=None):
    """Causal multi-head attention on a single sequence x[S, D].

    Returns (y[S, D], A[H, S, S]) — A is the post-softmax attention map,
    consumed by token-importance (paper Eq. 6 / Fig. 4).
    """
    s, d = x.shape
    hd = d // n_heads
    q = (x @ wq).reshape(s, n_heads, hd).transpose(1, 0, 2)
    k = (x @ wk).reshape(s, n_heads, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(s, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    if mask is not None:  # key-validity mask [S]
        causal = causal & mask[None, :]
    scores = jnp.where(causal[None], scores, -1e30)
    a = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("hqk,hkd->hqd", a, v).transpose(1, 0, 2).reshape(s, d)
    return y @ wo, a


def moe_ffn_ref(x, w1, w3, w2):
    """SwiGLU expert FFN: (silu(x@w1) * (x@w3)) @ w2."""
    h = silu(x @ w1) * (x @ w3)
    return h @ w2


def unpack_ref(qweight, bits: int, k: int):
    """jnp twin of packing.unpack_bits -> int32[K, N]."""
    vpw = VALS_PER_WORD[bits]
    mask = jnp.uint32(2**bits - 1)
    fields = [((qweight >> jnp.uint32(i * bits)) & mask).astype(jnp.int32)
              for i in range(vpw)]
    full = jnp.stack(fields, axis=1).reshape(qweight.shape[0] * vpw, -1)
    return full[:k]


def dequant_ref(qweight, scales, zeros, bits: int, k: int):
    """Unpack + group-wise dequantize -> f32[K, N]."""
    q = unpack_ref(qweight, bits, k).astype(jnp.float32)
    g = k // GROUP_SIZE
    qg = q.reshape(g, GROUP_SIZE, -1)
    w = (qg - zeros[:, None, :]) * scales[:, None, :]
    return w.reshape(k, -1)


def quant_matmul_ref(x, qweight, scales, zeros, bits: int):
    """y = x @ dequant(qweight)  for 2/3/4-bit packed weights."""
    k = x.shape[-1]
    return x @ dequant_ref(qweight, scales, zeros, bits, k)


def debinarize_ref(packed, scales, k: int):
    """jnp twin of packing.debinarize: w = (2*btilde - 1) * s_n."""
    fields = [((packed >> jnp.uint32(i)) & jnp.uint32(1)).astype(jnp.float32)
              for i in range(32)]
    b = jnp.stack(fields, axis=1).reshape(packed.shape[0] * 32, -1)[:k]
    return (2.0 * b - 1.0) * scales[None, :]


def binary_matmul_ref(x, packed, scales, k: int):
    """Paper Eq. 10: s * (sum_{b=1} x_j - sum_{b=0} x_j), vectorized."""
    return x @ debinarize_ref(packed, scales, k)


def token_importance_ref(x, a):
    """Paper Eq. 6:  I_j = ||t_j||_1 * mean_{i >= j} A[i, j].

    x: [S, D] token hidden states; a: [H, S, S] post-softmax attention.
    The attention-received column mean is averaged over heads.
    """
    s = x.shape[0]
    amean = a.mean(axis=0)                      # [S(query), S(key)]
    qi = jnp.arange(s)[:, None]                 # query index
    kj = jnp.arange(s)[None, :]                 # key index
    future = (qi >= kj).astype(amean.dtype)
    col = (amean * future).sum(axis=0)          # sum over queries i >= j
    denom = jnp.maximum(s - jnp.arange(s), 1).astype(amean.dtype)
    return jnp.sum(jnp.abs(x), axis=-1) * (col / denom)
