"""Quantized-weight bit-packing (canonical spec; rust twin: quant/pack.rs).

Layouts (all little-endian u32 words):

* b-bit (b in {2,3,4}), weight matrix W[K, N] quantized group-wise along
  K with GROUP_SIZE rows per group:
    - qweight: u32[K_words, N], K_words = ceil(K / VPW[b]); word w of
      column n holds rows r = w*VPW + i in bit-field [i*b, i*b + b).
      (3-bit packs 10 values in the low 30 bits; top 2 bits are zero.)
    - scales, zeros: f32[K/GROUP, N]; dequant  w = (q - z) * s.
* 1-bit: bit-change transform (paper Eq. 9): btilde = (sign(w)+1)/2,
  packed 32 rows per word (bit i of word w = row w*32+i), plus
  per-column scale s_n (XNOR-Net per-filter analogue; see DESIGN.md —
  the paper's scalar-per-matrix s is available via ``scalar_scale``).
"""

from __future__ import annotations

import numpy as np

from ..config import GROUP_SIZE, VALS_PER_WORD


def quantize_groupwise(w: np.ndarray, bits: int, group: int = GROUP_SIZE):
    """Asymmetric min/max group-wise quantization (the non-GPTQ baseline).

    Returns (q[K,N] int32 in [0, 2^bits-1], scales[K/g,N], zeros[K/g,N]).
    """
    k, n = w.shape
    assert k % group == 0, (k, group)
    g = k // group
    wg = w.reshape(g, group, n)
    lo = wg.min(axis=1)                      # [g, n]
    hi = wg.max(axis=1)
    qmax = float(2**bits - 1)
    scales = np.maximum((hi - lo) / qmax, 1e-8).astype(np.float32)
    zeros = (-lo / scales).astype(np.float32)  # float zero-point
    q = np.clip(np.round(wg / scales[:, None, :] + zeros[:, None, :]),
                0, qmax).astype(np.int32)
    return q.reshape(k, n), scales, zeros


def dequantize_groupwise(q: np.ndarray, scales: np.ndarray,
                         zeros: np.ndarray, group: int = GROUP_SIZE):
    k, n = q.shape
    g = k // group
    qg = q.reshape(g, group, n).astype(np.float32)
    return ((qg - zeros[:, None, :]) * scales[:, None, :]).reshape(k, n)


def pack_bits(q: np.ndarray, bits: int) -> np.ndarray:
    """Pack int levels q[K,N] into u32[K_words, N] per the layout above."""
    vpw = VALS_PER_WORD[bits]
    k, n = q.shape
    k_words = (k + vpw - 1) // vpw
    padded = np.zeros((k_words * vpw, n), dtype=np.uint32)
    padded[:k] = q.astype(np.uint32)
    padded = padded.reshape(k_words, vpw, n)
    out = np.zeros((k_words, n), dtype=np.uint32)
    for i in range(vpw):
        out |= padded[:, i, :] << np.uint32(i * bits)
    return out


def unpack_bits(packed: np.ndarray, bits: int, k: int) -> np.ndarray:
    """Inverse of pack_bits -> int32[K, N]."""
    vpw = VALS_PER_WORD[bits]
    k_words, n = packed.shape
    mask = np.uint32(2**bits - 1)
    out = np.zeros((k_words, vpw, n), dtype=np.int32)
    for i in range(vpw):
        out[:, i, :] = ((packed >> np.uint32(i * bits)) & mask).astype(np.int32)
    return out.reshape(k_words * vpw, n)[:k]


# ---------------------------------------------------------------------------
# 1-bit
# ---------------------------------------------------------------------------

def binarize(w: np.ndarray, scalar_scale: bool = False):
    """Sign-binarize with the bit-change transform (paper Eqs. 7-9).

    Returns (btilde_packed u32[ceil(K/32), N], scales f32[N]).
    """
    k, n = w.shape
    sign = np.where(w >= 0, 1.0, -1.0).astype(np.float32)
    if scalar_scale:
        s = np.full(n, np.abs(w).sum() / (k * n), dtype=np.float32)
    else:
        s = np.abs(w).mean(axis=0).astype(np.float32)  # per output column
    btilde = ((sign + 1) / 2).astype(np.uint32)        # {0,1}
    k_words = (k + 31) // 32
    padded = np.zeros((k_words * 32, n), dtype=np.uint32)
    padded[:k] = btilde
    padded = padded.reshape(k_words, 32, n)
    packed = np.zeros((k_words, n), dtype=np.uint32)
    for i in range(32):
        packed |= padded[:, i, :] << np.uint32(i)
    return packed, s


def debinarize(packed: np.ndarray, scales: np.ndarray, k: int) -> np.ndarray:
    """Reconstruct f32 weights: w = (2*btilde - 1) * s_n."""
    k_words, n = packed.shape
    bits = np.zeros((k_words, 32, n), dtype=np.float32)
    for i in range(32):
        bits[:, i, :] = ((packed >> np.uint32(i)) & np.uint32(1)).astype(np.float32)
    b = bits.reshape(k_words * 32, n)[:k]
    return (2.0 * b - 1.0) * scales[None, :]
