"""L1 Pallas kernel: causal multi-head attention.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over
heads; each invocation holds one head's Q/K/V tile in VMEM, runs the
QKᵀ matmul on the MXU, a numerically-stable softmax on the VPU, and the
AV matmul back on the MXU.  At the sequence lengths this repo serves
(<= 512) a whole head fits in one VMEM tile, so no K/V streaming loop is
needed; ``roofline.py`` accounts for both regimes.

interpret=True everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md), and interpret-lowered
kernels become plain HLO that the rust runtime runs as-is.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_head_kernel(q_ref, k_ref, v_ref, mask_ref, y_ref, a_ref, *, scale):
    q = q_ref[0]            # [S, hd]
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    scores = jnp.dot(q, k.T) * scale                       # MXU
    causal = jnp.tril(jnp.ones((s, s), dtype=jnp.bool_))
    valid = causal & (mask_ref[...] > 0)[None, :]
    scores = jnp.where(valid, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)            # stable softmax
    e = jnp.exp(scores - m)
    a = e / jnp.sum(e, axis=-1, keepdims=True)
    a_ref[0] = a
    y_ref[0] = jnp.dot(a, v)                               # MXU


def attention(x, wq, wk, wv, wo, n_heads: int, mask=None):
    """Pallas twin of ref.attention_ref -> (y[S,D], A[H,S,S])."""
    s, d = x.shape
    hd = d // n_heads
    if mask is None:
        mask = jnp.ones((s,), dtype=jnp.int32)
    q = (x @ wq).reshape(s, n_heads, hd).transpose(1, 0, 2)
    k = (x @ wk).reshape(s, n_heads, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(s, n_heads, hd).transpose(1, 0, 2)
    kern = functools.partial(_attn_head_kernel, scale=1.0 / (hd ** 0.5))
    y_h, a = pl.pallas_call(
        kern,
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((s,), lambda h: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, s), lambda h: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_heads, s, hd), x.dtype),
            jax.ShapeDtypeStruct((n_heads, s, s), x.dtype),
        ],
        interpret=True,
    )(q, k, v, mask.astype(jnp.int32))
    y = y_h.transpose(1, 0, 2).reshape(s, d)
    return y @ wo, a
