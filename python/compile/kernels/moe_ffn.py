"""L1 Pallas kernel: SwiGLU expert FFN  y = (silu(x@w1) * (x@w3)) @ w2.

TPU mapping: grid over token tiles of BM rows; per invocation the three
weight matrices are resident in VMEM (they are the per-expert weights —
at serving shapes D*F*3*4B ≈ 384 KiB for the tiny config, within the
~16 MiB VMEM budget; roofline.py checks this per config) and the token
tile streams through.  Both matmuls hit the MXU; the silu/mul gate runs
on the VPU between them, fused in one kernel so the [BM, F] intermediate
never round-trips to HBM — this is the fusion the paper gets from its
CUDA kernels and the core of the L2 fusion story.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, y_ref):
    x = x_ref[...]
    h1 = jnp.dot(x, w1_ref[...])              # MXU
    h3 = jnp.dot(x, w3_ref[...])              # MXU
    g = h1 / (1.0 + jnp.exp(-h1)) * h3        # VPU: silu * up
    y_ref[...] = jnp.dot(g, w2_ref[...])      # MXU


def moe_ffn(x, w1, w3, w2, block_m: int = 128):
    """Pallas twin of ref.moe_ffn_ref; x[M, D] -> y[M, D]."""
    m, d = x.shape
    f = w1.shape[1]
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)
