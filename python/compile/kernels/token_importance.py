"""L1 Pallas kernel: ODP token-importance metric (paper Eq. 6).

    I_j = ||t_j||_1 * mean_{i >= j} A[i, j]

x[S, D] are token hidden states entering the MoE layer; A[H, S, S] is
the post-softmax attention of the same block (averaged over heads).
Single-invocation kernel: at serving sequence lengths the whole A-mean
fits in VMEM; the column masked-sum and the L1 norm are VPU reductions.
Appendix A.9's cost analysis (n² + n + mn + n log n FLOPs) applies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _token_importance_kernel(x_ref, a_ref, i_ref):
    x = x_ref[...]                           # [S, D]
    a = a_ref[...]                           # [H, S, S]
    s = x.shape[0]
    amean = jnp.mean(a, axis=0)              # [S, S], head-averaged
    qi = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    future = (qi >= kj).astype(amean.dtype)
    col = jnp.sum(amean * future, axis=0)    # Σ_{i>=j} A[i,j]
    denom = jnp.maximum(s - jax.lax.iota(jnp.int32, s), 1).astype(amean.dtype)
    l1 = jnp.sum(jnp.abs(x), axis=-1)        # ||t_j||_1
    i_ref[...] = l1 * (col / denom)


def token_importance(x, a):
    """Pallas twin of ref.token_importance_ref -> I[S]."""
    s, _ = x.shape
    return pl.pallas_call(
        _token_importance_kernel,
        out_shape=jax.ShapeDtypeStruct((s,), x.dtype),
        interpret=True,
    )(x, a)
