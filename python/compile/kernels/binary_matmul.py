"""L1 Pallas kernel: 1-bit (binarized) matmul, paper Eqs. 7-10.

Weights are sign-binarized with the bit-change transform
(btilde = (sign(w)+1)/2) and packed 32 rows per u32 word; a per-column
scale s_n reconstructs w = (2*btilde - 1) * s_n.

TPU note (DESIGN.md §Hardware-Adaptation): the paper's Eq. 10 add/sub
formulation is an XNOR/popcount trick aimed at scalar ALUs.  On TPU the
MXU only consumes dense tiles, so the profitable schedule is: unpack
bits on the VPU -> map {0,1} to {-1,+1} -> one MXU dot -> one broadcast
column-scale multiply.  That preserves Eq. 10's arithmetic exactly
(x @ ((2b-1) s) == s * (Σ_{b=1} x − Σ_{b=0} x)) while keeping the MXU
fed; the unpacked tile lives only in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binary_matmul_kernel(x_ref, p_ref, s_ref, y_ref, *, k):
    p = p_ref[...]                                     # [K_words, BN] u32
    fields = [((p >> jnp.uint32(i)) & jnp.uint32(1)).astype(jnp.float32)
              for i in range(32)]                      # VPU unpack
    b = jnp.stack(fields, axis=1).reshape(p.shape[0] * 32, -1)[:k]
    w = 2.0 * b - 1.0                                  # {0,1} -> {-1,+1}
    acc = jnp.dot(x_ref[...], w)                       # MXU
    y_ref[...] = acc * s_ref[...][None, :]             # per-column scale


def binary_matmul(x, packed, scales, block_n: int = 128):
    """Pallas twin of ref.binary_matmul_ref; x[M,K] -> y[M,N]."""
    m, k = x.shape
    k_words, n = packed.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    kern = functools.partial(_binary_matmul_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k_words, bn), lambda j: (0, j)),
            pl.BlockSpec((bn,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, packed, scales)
