"""L1 performance model: VMEM footprint + MXU utilization estimates.

Pallas runs interpret=True on this CPU image, so kernel wall-clock is
meaningless; what we *can* verify at build time is the TPU resource
model implied by each kernel's BlockSpecs (DESIGN.md §Hardware-
Adaptation):

  * VMEM footprint per grid invocation must fit the ~16 MiB budget,
  * MXU utilization estimate = useful MACs / (MXU-shaped tile MACs),
    i.e. how well the tile dims align to the 128x128 systolic array,
  * HBM traffic per kernel (the quantity PMQ compresses).

`python -m compile.kernels.roofline` prints the table for a config and
is recorded in EXPERIMENTS.md §Perf; pytest guards the VMEM budget.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..config import GROUP_SIZE, VALS_PER_WORD, ModelConfig

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM budget (v4/v5-class)
MXU = 128                      # systolic array edge


def _pad(x: int, m: int) -> int:
    return -(-x // m) * m


def mxu_utilization(m: int, k: int, n: int) -> float:
    """Useful MACs / MACs of the MXU-padded tile."""
    useful = m * k * n
    padded = _pad(m, 8) * _pad(k, MXU) * _pad(n, MXU)
    return useful / padded


@dataclass
class KernelEstimate:
    name: str
    vmem_bytes: int
    mxu_util: float
    hbm_bytes: int
    flops: int

    def row(self) -> list[str]:
        return [
            self.name,
            f"{self.vmem_bytes / 1024:.1f} KiB",
            f"{self.mxu_util * 100:.1f}%",
            f"{self.hbm_bytes / 1024:.1f} KiB",
            f"{self.flops / 1e6:.2f} MF",
        ]


def attention_estimate(cfg: ModelConfig, seq: int | None = None) -> KernelEstimate:
    s = seq or cfg.max_seq
    hd = cfg.head_dim
    # per grid step (one head): q,k,v tiles + scores + out
    vmem = (3 * s * hd + s * s + s * hd) * 4
    flops = 2 * s * s * hd * 2  # QK^T and AV
    hbm = (3 * s * hd + s * s + s * hd) * 4
    return KernelEstimate("attention(head)", vmem,
                          mxu_utilization(s, hd, s), hbm, flops)


def moe_ffn_estimate(cfg: ModelConfig, block_m: int | None = None) -> KernelEstimate:
    bm = block_m or cfg.prefill_tile
    d, f = cfg.d_model, cfg.d_ff
    vmem = (bm * d + 3 * d * f + bm * f + bm * d) * 4
    flops = 2 * bm * d * f * 3
    hbm = (bm * d + 3 * d * f + bm * d) * 4
    return KernelEstimate(f"moe_ffn(bm={bm})", vmem,
                          mxu_utilization(bm, d, f), hbm, flops)


def quant_matmul_estimate(cfg: ModelConfig, bits: int,
                          block_n: int = 128) -> KernelEstimate:
    m, k = cfg.prefill_tile, cfg.d_model
    n = min(block_n, cfg.d_ff)
    vpw = VALS_PER_WORD[bits]
    kw = -(-k // vpw)
    g = k // GROUP_SIZE
    # packed words + scales/zeros + x tile + dequantized w tile (scratch)
    vmem = (kw * n + 2 * g * n + m * k + k * n + m * n) * 4
    flops = 2 * m * k * n
    hbm = (kw * n + 2 * g * n + m * k + m * n) * 4  # w never re-written
    return KernelEstimate(f"quant_matmul(b={bits})", vmem,
                          mxu_utilization(m, k, n), hbm, flops)


def binary_matmul_estimate(cfg: ModelConfig, block_n: int = 128) -> KernelEstimate:
    m, k = cfg.prefill_tile, cfg.d_model
    n = min(block_n, cfg.d_ff)
    kw = -(-k // 32)
    vmem = (kw * n + n + m * k + k * n + m * n) * 4
    flops = 2 * m * k * n
    hbm = (kw * n + n + m * k + m * n) * 4
    return KernelEstimate("binary_matmul", vmem,
                          mxu_utilization(m, k, n), hbm, flops)


def all_estimates(cfg: ModelConfig) -> list[KernelEstimate]:
    return [
        attention_estimate(cfg),
        moe_ffn_estimate(cfg),
        quant_matmul_estimate(cfg, 2),
        quant_matmul_estimate(cfg, 3),
        binary_matmul_estimate(cfg),
    ]


def hbm_compression_ratio(cfg: ModelConfig, bits: int) -> float:
    """Weight-traffic ratio vs f32 for the expert matmuls (the L1-level
    quantity the paper's memory saving comes from)."""
    f32 = quant_matmul_estimate(cfg, 2)  # shapes only; recompute below
    d, f = cfg.d_model, cfg.d_ff
    dense_w = d * f * 4
    vpw = VALS_PER_WORD[bits]
    packed_w = (-(-d // vpw)) * f * 4 + 2 * (d // GROUP_SIZE) * f * 4
    _ = f32
    return packed_w / dense_w


def main() -> None:
    from ..config import CONFIGS
    import sys

    name = sys.argv[1] if len(sys.argv) > 1 else "tiny"
    cfg = CONFIGS[name]()
    print(f"L1 roofline estimates — config {cfg.name} "
          f"(VMEM budget {VMEM_BYTES >> 20} MiB, MXU {MXU}x{MXU})")
    print(f"{'kernel':24} {'VMEM':>12} {'MXU util':>9} {'HBM/call':>12} {'FLOPs':>10}")
    for e in all_estimates(cfg):
        r = e.row()
        print(f"{r[0]:24} {r[1]:>12} {r[2]:>9} {r[3]:>12} {r[4]:>10}")
        assert e.vmem_bytes < VMEM_BYTES, f"{e.name} exceeds VMEM budget"
    for bits in (2, 3):
        print(f"expert-weight HBM traffic at {bits}-bit: "
              f"{hbm_compression_ratio(cfg, bits) * 100:.1f}% of f32")


if __name__ == "__main__":
    main()
