"""AOT export: lower the L2 model (+L1 kernels) to HLO text artifacts.

Runs once at build time (`make artifacts`); the rust runtime loads the
HLO text via PJRT and python never appears on the request path.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Emitted artifacts (per DESIGN.md §3):
    config.json           model + tokenizer + packing configuration
    weights.mcwt          trained f32 weights (MCWT format)
    train_log.json        build-time loss curve (EXPERIMENTS.md §E2E)
    golden.mcwt           fixed-input logits/probs/importance for rust parity tests
    manifest.json         artifact -> ordered input/output specs
    model_fwd.hlo.txt     tokens[S] -> logits[S,V]       (full fwd, kernels inlined)
    gate.hlo.txt          x[T,D], wg[D,E] -> probs[T,E]
    expert_ffn_f32.hlo.txt  x[T,D], w1,w3,w2 -> y[T,D]
    expert_ffn_q2/q3.hlo.txt  x[T,D], (qw,s,z)x3 -> y[T,D]
    expert_ffn_b1.hlo.txt     x[T,D], (packed,scale)x3 -> y[T,D]
    attention.hlo.txt     x[S,D], mask[S], wq..wo -> (y[S,D], A[H,S,S])
    token_importance.hlo.txt  x[S,D], A[H,S,S] -> I[S]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as cfg_mod
from . import mcwt, train
from .config import GROUP_SIZE, VALS_PER_WORD, ModelConfig
from .kernels import ref
from .kernels.attention import attention as attention_k
from .kernels.binary_matmul import binary_matmul
from .kernels.moe_ffn import moe_ffn
from .kernels.quant_matmul import quant_matmul
from .kernels.token_importance import token_importance
from .model import forward_seq, gate_probs, param_names


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(d) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32", jnp.uint32: "u32"}[d]


class Exporter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.manifest: dict[str, dict] = {}

    def export(self, name: str, fn, inputs: list[tuple[str, list[int], object]],
               outputs: list[tuple[str, list[int]]]):
        specs = [_spec(shape, dt) for _, shape, dt in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.manifest[name] = {
            "inputs": [{"name": n, "shape": s, "dtype": _dtype_name(dt)}
                       for n, s, dt in inputs],
            "outputs": [{"name": n, "shape": s} for n, s in outputs],
        }
        print(f"  exported {name}: {len(text)} chars, "
              f"{len(inputs)} inputs", flush=True)


def packed_shapes(k: int, n: int, bits: int):
    """(qweight, scales, zeros) shapes for a [K, N] matrix at `bits`."""
    if bits == 1:
        return ((k + 31) // 32, n), (n,), None
    vpw = VALS_PER_WORD[bits]
    kw = (k + vpw - 1) // vpw
    return (kw, n), (k // GROUP_SIZE, n), (k // GROUP_SIZE, n)


def export_all(cfg: ModelConfig, params: dict, out_dir: str):
    ex = Exporter(cfg, out_dir)
    d, f, e, h = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.n_heads
    s, t, v = cfg.max_seq, cfg.prefill_tile, cfg.vocab_size

    # --- full forward (fast scoring path). Params are trailing args in
    # canonical sorted-name order so rust can feed them positionally.
    names = param_names(cfg)

    def model_fwd(tokens, *flat):
        p = dict(zip(names, flat))
        logits, _ = forward_seq(p, cfg, tokens, use_kernels=True)
        return (logits,)

    ex.export(
        "model_fwd", model_fwd,
        [("tokens", [s], jnp.int32)] +
        [(n, list(params[n].shape), jnp.float32) for n in names],
        [("logits", [s, v])],
    )

    # --- router gate
    ex.export(
        "gate", lambda x, wg: (gate_probs(x, wg),),
        [("x", [t, d], jnp.float32), ("wg", [d, e], jnp.float32)],
        [("probs", [t, e])],
    )

    # --- expert FFN, fp32 (pallas moe_ffn kernel)
    ex.export(
        "expert_ffn_f32", lambda x, w1, w3, w2: (moe_ffn(x, w1, w3, w2),),
        [("x", [t, d], jnp.float32), ("w1", [d, f], jnp.float32),
         ("w3", [d, f], jnp.float32), ("w2", [f, d], jnp.float32)],
        [("y", [t, d])],
    )

    # --- expert FFN, quantized 2/3-bit (fused unpack->dequant->matmul)
    for bits in (2, 3):
        q1, s1, z1 = packed_shapes(d, f, bits)
        q2, s2, z2 = packed_shapes(f, d, bits)

        def qffn(x, qw1, sc1, zp1, qw3, sc3, zp3, qw2, sc2, zp2, _b=bits):
            h1 = quant_matmul(x, qw1, sc1, zp1, _b)
            h3 = quant_matmul(x, qw3, sc3, zp3, _b)
            g = h1 / (1.0 + jnp.exp(-h1)) * h3
            return (quant_matmul(g, qw2, sc2, zp2, _b),)

        ex.export(
            f"expert_ffn_q{bits}", qffn,
            [("x", [t, d], jnp.float32),
             ("qw1", list(q1), jnp.uint32), ("s1", list(s1), jnp.float32),
             ("z1", list(z1), jnp.float32),
             ("qw3", list(q1), jnp.uint32), ("s3", list(s1), jnp.float32),
             ("z3", list(z1), jnp.float32),
             ("qw2", list(q2), jnp.uint32), ("s2", list(s2), jnp.float32),
             ("z2", list(z2), jnp.float32)],
            [("y", [t, d])],
        )

    # --- expert FFN, binary (Eq. 10)
    p1, sb1, _ = packed_shapes(d, f, 1)
    p2, sb2, _ = packed_shapes(f, d, 1)

    def bffn(x, pk1, sc1, pk3, sc3, pk2, sc2):
        h1 = binary_matmul(x, pk1, sc1)
        h3 = binary_matmul(x, pk3, sc3)
        g = h1 / (1.0 + jnp.exp(-h1)) * h3
        return (binary_matmul(g, pk2, sc2),)

    ex.export(
        "expert_ffn_b1", bffn,
        [("x", [t, d], jnp.float32),
         ("p1", list(p1), jnp.uint32), ("s1", list(sb1), jnp.float32),
         ("p3", list(p1), jnp.uint32), ("s3", list(sb1), jnp.float32),
         ("p2", list(p2), jnp.uint32), ("s2", list(sb2), jnp.float32)],
        [("y", [t, d])],
    )

    # --- attention block (also emits A for token importance)
    def attn_fn(x, mask, wq, wk, wv, wo):
        y, a = attention_k(x, wq, wk, wv, wo, h, mask)
        return (y, a)

    ex.export(
        "attention", attn_fn,
        [("x", [s, d], jnp.float32), ("mask", [s], jnp.int32)] +
        [(n, [d, d], jnp.float32) for n in ("wq", "wk", "wv", "wo")],
        [("y", [s, d]), ("a", [h, s, s])],
    )

    # --- token importance (paper Eq. 6)
    ex.export(
        "token_importance", lambda x, a: (token_importance(x, a),),
        [("x", [s, d], jnp.float32), ("a", [h, s, s], jnp.float32)],
        [("importance", [s])],
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as fo:
        json.dump({"config": cfg.name, "param_order": names,
                   "artifacts": ex.manifest}, fo, indent=2)


def write_golden(cfg: ModelConfig, params: dict, out_dir: str):
    """Fixed-input reference outputs for rust parity tests."""
    rng = np.random.default_rng(12345)
    toks = rng.integers(1, cfg.vocab_size, size=cfg.max_seq).astype(np.int32)
    logits, aux = forward_seq(
        {k: jnp.asarray(v) for k, v in params.items()}, cfg,
        jnp.asarray(toks), collect_aux=True)
    mcwt.write(os.path.join(out_dir, "golden.mcwt"), {
        "tokens": toks.astype(np.float32),
        "logits": np.asarray(logits),
        "probs_l0": np.asarray(aux["probs"][0]),
        "importance_l0": np.asarray(aux["importance"][0]),
        "attn_l0": np.asarray(aux["attn"][0]),
    })
    print(f"  golden: logits[0,:4]={np.asarray(logits)[0, :4]}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny", choices=list(cfg_mod.CONFIGS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat: path to model_fwd stamp (Makefile)")
    ap.add_argument("--force-train", action="store_true")
    ap.add_argument("--skip-hlo", action="store_true")
    args = ap.parse_args()

    cfg = cfg_mod.get(args.config)
    out_dir = args.out_dir
    if args.out:  # Makefile passes artifacts/model.hlo.txt-style path
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    wpath = os.path.join(out_dir, "weights.mcwt")
    lpath = os.path.join(out_dir, "train_log.json")
    if args.force_train or not os.path.exists(wpath):
        print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
              f"{cfg.train_steps} steps)...", flush=True)
        params, _ = train.train_and_save(cfg, wpath, lpath)
        params = {k: np.asarray(v) for k, v in params.items()}
    else:
        print(f"weights exist, skipping training: {wpath}", flush=True)
        params = mcwt.read(wpath)

    with open(os.path.join(out_dir, "config.json"), "w") as f:
        f.write(cfg.to_json())

    write_golden(cfg, params, out_dir)

    if not args.skip_hlo:
        print("exporting HLO artifacts...", flush=True)
        export_all(cfg, params, out_dir)
    print("aot: done", flush=True)


if __name__ == "__main__":
    main()
