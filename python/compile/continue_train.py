"""Continue training from existing weights (build-time utility).

Usage: python -m compile.continue_train [--steps 1500] [--lr 1.5e-3]
Loads artifacts/weights.mcwt, trains further on the same corpus
distribution, saves back, and refreshes golden.mcwt + HLO artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import config as cfg_mod
from . import datagen, mcwt
from .aot import export_all, write_golden
from .train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--lr", type=float, default=1.5e-3)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    cfg = cfg_mod.get(args.config)
    wpath = os.path.join(args.out_dir, "weights.mcwt")
    params = {k: jnp.asarray(v) for k, v in mcwt.read(wpath).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    grad_fn, adam = make_train_step(cfg)
    rng = np.random.default_rng(args.seed)
    text = datagen.TextChannel()
    t0 = time.time()
    log = []
    step = 0
    for x, y in datagen.batches(rng, text, args.steps, cfg.train_batch,
                                cfg.train_seq):
        step += 1
        cos = 0.5 * (1 + np.cos(np.pi * step / args.steps))
        lr = args.lr * (0.1 + 0.9 * cos)
        loss, grads = grad_fn(params, jnp.asarray(x), jnp.asarray(y))
        params, m, v = adam(params, grads, m, v, step, lr)
        if step % 50 == 0 or step == 1:
            entry = {"step": step, "loss": float(loss),
                     "elapsed_s": round(time.time() - t0, 1)}
            log.append(entry)
            print(f"  +step {step:5d}  loss {entry['loss']:.4f}  "
                  f"{entry['elapsed_s']:7.1f}s", flush=True)

    np_params = {k: np.asarray(p) for k, p in params.items()}
    mcwt.write(wpath, np_params)
    lpath = os.path.join(args.out_dir, "train_log.json")
    try:
        prev = json.load(open(lpath))
    except Exception:
        prev = {"log": []}
    prev.setdefault("continued", []).append(
        {"steps": args.steps, "lr": args.lr, "log": log})
    prev["final_loss"] = log[-1]["loss"] if log else prev.get("final_loss")
    json.dump(prev, open(lpath, "w"), indent=2)

    print("refreshing golden + HLO artifacts...", flush=True)
    write_golden(cfg, np_params, args.out_dir)
    export_all(cfg, np_params, args.out_dir)
    print("continue_train: done", flush=True)


if __name__ == "__main__":
    main()
