"""Build-time trainer for the synthetic MoE LM (runs once via `make
artifacts`; python is never on the request path).

Hand-rolled Adam (no optax dependency in this image).  The trained
weights freeze the "pre-trained MoE-LLM" that MC then compresses
training-free, exactly as the paper operates on a frozen Mixtral.
A load-balancing auxiliary loss (Shazeer-style) keeps all experts
alive while still leaving the natural utilization imbalance that
PMQ's significance analysis exploits (verified by Fig-3 bench).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, mcwt
from .config import ModelConfig
from .model import forward, init_params, loss_fn


def make_train_step(cfg: ModelConfig):
    def train_loss(params, x, y):
        return loss_fn(params, cfg, x, y)

    grad_fn = jax.jit(jax.value_and_grad(train_loss))

    @jax.jit
    def adam_update(params, grads, m, v, step, lr):
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            new_m[k] = b1 * m[k] + (1 - b1) * g
            new_v[k] = b2 * v[k] + (1 - b2) * g * g
            mhat = new_m[k] / (1 - b1 ** step)
            vhat = new_v[k] / (1 - b2 ** step)
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v

    return grad_fn, adam_update


def train(cfg: ModelConfig, log_every: int = 25,
          progress: bool = True) -> tuple[dict, list[dict]]:
    """Train the MoE LM on the synthetic general split; returns
    (params, loss_log)."""
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(cfg, key)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    grad_fn, adam_update = make_train_step(cfg)

    rng = np.random.default_rng(cfg.seed + 1)
    text = datagen.TextChannel()
    log: list[dict] = []
    t0 = time.time()
    step = 0
    for x, y in datagen.batches(rng, text, cfg.train_steps,
                                cfg.train_batch, cfg.train_seq):
        step += 1
        # cosine LR decay with short warmup
        warm = min(step / 50.0, 1.0)
        cos = 0.5 * (1 + np.cos(np.pi * step / cfg.train_steps))
        lr = cfg.lr * warm * (0.1 + 0.9 * cos)
        loss, grads = grad_fn(params, jnp.asarray(x), jnp.asarray(y))
        params, m, v = adam_update(params, grads, m, v, step, lr)
        if step % log_every == 0 or step == 1:
            entry = {"step": step, "loss": float(loss), "lr": float(lr),
                     "elapsed_s": round(time.time() - t0, 1)}
            log.append(entry)
            if progress:
                print(f"  step {step:5d}  loss {entry['loss']:.4f}  "
                      f"lr {lr:.2e}  {entry['elapsed_s']:7.1f}s", flush=True)
    return params, log


def train_and_save(cfg: ModelConfig, weights_path: str, log_path: str):
    params, log = train(cfg)
    mcwt.write(weights_path, {k: np.asarray(p) for k, p in params.items()})
    with open(log_path, "w") as f:
        json.dump({"config": cfg.name, "steps": cfg.train_steps,
                   "final_loss": log[-1]["loss"] if log else None,
                   "log": log}, f, indent=2)
    return params, log
