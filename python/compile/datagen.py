"""Synthetic corpus generator (build-time twin of ``rust/src/data``).

The corpus substitutes for C4/WikiText2/MATH (see DESIGN.md §2): a
mixture of 8 procedural task grammars over a 256-token vocabulary plus a
Zipfian Markov "text" channel.  The *general* split mixes all channels
(C4 analogue); the *arith* split is modadd-only (MATH analogue); the
*text* split is the Markov channel alone (WikiText2-PPL analogue).

Formats are identical to the rust generators so that a model trained
here is evaluated on-distribution by the rust harness.  RNG streams need
not match across languages — only the grammar does.
"""

from __future__ import annotations

import numpy as np

from .config import (
    BOS, EOS, NUM_BASE, NUM_COUNT, PAD, QRY, SEP, SYM_BASE, SYM_COUNT,
    TASK_BASE, TASK_NAMES, TXT_BASE, TXT_COUNT,
)


def _num(v: int) -> int:
    assert 0 <= v < NUM_COUNT
    return NUM_BASE + v


def _sym(v: int) -> int:
    assert 0 <= v < SYM_COUNT
    return SYM_BASE + v


# ---------------------------------------------------------------------------
# Task grammars.  Each returns (prompt_tokens, answer_tokens); training
# sequences are  [BOS, task_tag] + prompt + [SEP] + answer + [EOS].
# ---------------------------------------------------------------------------

def gen_copy(rng: np.random.Generator, n: int = 8):
    seq = [_sym(int(s)) for s in rng.integers(0, 16, size=n)]
    return seq, list(seq)


def gen_reverse(rng: np.random.Generator, n: int = 8):
    seq = [_sym(int(s)) for s in rng.integers(0, 16, size=n)]
    return seq, seq[::-1]


def gen_sortsym(rng: np.random.Generator, n: int = 8):
    vals = [int(s) for s in rng.integers(0, 16, size=n)]
    return [_sym(v) for v in vals], [_sym(v) for v in sorted(vals)]


def gen_modadd(rng: np.random.Generator, n: int = 0):
    a, b = int(rng.integers(0, NUM_COUNT)), int(rng.integers(0, NUM_COUNT))
    return [_num(a), _num(b)], [_num((a + b) % NUM_COUNT)]


def gen_recall(rng: np.random.Generator, n: int = 4):
    keys = rng.permutation(32)[:n]
    vals = rng.integers(32, 64, size=n)
    prompt = []
    for k, v in zip(keys, vals):
        prompt += [_sym(int(k)), _sym(int(v))]
    q = int(rng.integers(0, n))
    prompt += [QRY, _sym(int(keys[q]))]
    return prompt, [_sym(int(vals[q]))]


def gen_majority(rng: np.random.Generator, n: int = 9):
    choices = rng.permutation(8)[:2]
    k = int(rng.integers(n // 2 + 1, n))  # strict majority count
    seq = [int(choices[0])] * k + [int(choices[1])] * (n - k)
    rng.shuffle(seq)
    return [_sym(s) for s in seq], [_sym(int(choices[0]))]


def gen_counting(rng: np.random.Generator, n: int = 10):
    target = int(rng.integers(0, 8))
    seq = [int(s) for s in rng.integers(0, 8, size=n)]
    cnt = seq.count(target)
    return [_sym(target), QRY] + [_sym(s) for s in seq], [_num(cnt)]


def gen_induction(rng: np.random.Generator, n: int = 6):
    # pattern: a b  ... filler ...  a -> b   (classic induction head probe)
    a, b = (int(x) for x in rng.permutation(16)[:2])
    filler = [_sym(int(s) + 16) for s in rng.integers(0, 16, size=n)]
    return [_sym(a), _sym(b)] + filler + [_sym(a)], [_sym(b)]


TASK_GENS = [gen_copy, gen_reverse, gen_sortsym, gen_modadd,
             gen_recall, gen_majority, gen_counting, gen_induction]
assert len(TASK_GENS) == len(TASK_NAMES)


def task_sequence(rng: np.random.Generator, task_id: int) -> list[int]:
    prompt, answer = TASK_GENS[task_id](rng)
    return [BOS, TASK_BASE + task_id] + prompt + [SEP] + answer + [EOS]


# ---------------------------------------------------------------------------
# Zipfian Markov "text" channel (WikiText2 analogue)
# ---------------------------------------------------------------------------

class TextChannel:
    """Order-1 Markov chain over TXT tokens with Zipf-distributed rows.

    A fixed seed builds the transition table, so python (training) and
    rust (eval) sample from the *same* language.  The table construction
    must match ``rust/src/data/text.rs`` exactly: row i's successor
    ranks are a deterministic permutation from an LCG, with Zipf(1.2)
    probabilities over 12 successors.
    """

    FANOUT = 12
    ZIPF_S = 1.2
    LCG_MUL = 6364136223846793005
    LCG_INC = 1442695040888963407

    def __init__(self, table_seed: int = 0xC0FFEE):
        probs = 1.0 / np.arange(1, self.FANOUT + 1) ** self.ZIPF_S
        self.probs = probs / probs.sum()
        self.succ = np.zeros((TXT_COUNT, self.FANOUT), dtype=np.int64)
        state = np.uint64(table_seed)
        for i in range(TXT_COUNT):
            # deterministic successor permutation via LCG Fisher-Yates
            perm = list(range(TXT_COUNT))
            for j in range(TXT_COUNT - 1, 0, -1):
                state = np.uint64(
                    (int(state) * self.LCG_MUL + self.LCG_INC) % (1 << 64))
                k = int(state >> np.uint64(33)) % (j + 1)
                perm[j], perm[k] = perm[k], perm[j]
            self.succ[i] = perm[: self.FANOUT]

    def sample(self, rng: np.random.Generator, n: int) -> list[int]:
        cur = int(rng.integers(0, TXT_COUNT))
        out = []
        for _ in range(n):
            out.append(TXT_BASE + cur)
            cur = int(self.succ[cur, rng.choice(self.FANOUT, p=self.probs)])
        return out


# ---------------------------------------------------------------------------
# Corpus assembly
# ---------------------------------------------------------------------------

def pack_stream(rng: np.random.Generator, text: TextChannel, n_tokens: int,
                split: str = "general") -> np.ndarray:
    """Emit a contiguous token stream of >= n_tokens for LM training.

    split: "general" (70% tasks uniformly + 30% text), "arith"
    (modadd-only), "text" (Markov channel only).
    """
    out: list[int] = []
    while len(out) < n_tokens:
        if split == "text":
            out += [BOS] + text.sample(rng, 48) + [EOS]
        elif split == "arith":
            out += task_sequence(rng, 3)
        elif split == "general":
            if rng.random() < 0.3:
                out += [BOS] + text.sample(rng, 48) + [EOS]
            else:
                out += task_sequence(rng, int(rng.integers(0, 8)))
        else:
            raise ValueError(split)
    return np.array(out[:n_tokens], dtype=np.int32)


def batches(rng: np.random.Generator, text: TextChannel, steps: int,
            batch: int, seq: int, split: str = "general"):
    """Yield (x, y) next-token training batches of shape [batch, seq]."""
    for _ in range(steps):
        stream = pack_stream(rng, text, batch * (seq + 1), split)
        arr = stream.reshape(batch, seq + 1)
        yield arr[:, :-1], arr[:, 1:]
