"""MCWT weight interchange format (rust twin: rust/src/moe/weights.rs).

Layout (little-endian):
    bytes 0..4   magic b"MCWT"
    bytes 4..8   u32 version (1)
    bytes 8..12  u32 header length H
    bytes 12..12+H  JSON header: {"tensors": {name: {"dtype": "f32",
                    "shape": [...], "offset": int, "nbytes": int}}}
    then the raw tensor payload, 64-byte aligned per tensor.
"""

from __future__ import annotations

import json

import numpy as np

MAGIC = b"MCWT"
VERSION = 1
ALIGN = 64


def write(path: str, tensors: dict[str, np.ndarray]) -> None:
    entries: dict[str, dict] = {}
    offset = 0
    blobs: list[tuple[int, bytes]] = []
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        pad = (-offset) % ALIGN
        offset += pad
        raw = arr.tobytes()
        entries[name] = {"dtype": "f32", "shape": list(arr.shape),
                         "offset": offset, "nbytes": len(raw)}
        blobs.append((offset, raw))
        offset += len(raw)
    header = json.dumps({"tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(VERSION).tobytes())
        f.write(np.uint32(len(header)).tobytes())
        f.write(header)
        base = f.tell()
        for off, raw in blobs:
            f.seek(base + off)
            f.write(raw)


def read(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version = np.frombuffer(f.read(4), np.uint32)[0]
        assert version == VERSION, version
        hlen = int(np.frombuffer(f.read(4), np.uint32)[0])
        header = json.loads(f.read(hlen))
        base = f.tell()
        out = {}
        for name, meta in header["tensors"].items():
            f.seek(base + meta["offset"])
            raw = f.read(meta["nbytes"])
            out[name] = np.frombuffer(raw, np.float32).reshape(meta["shape"]).copy()
    return out
