"""L2: Mixtral-style MoE transformer in JAX (build-time only).

Two interchangeable compute paths produce bit-identical math:
  * ``use_kernels=True``  — calls the L1 Pallas kernels (interpret=True),
    used for the AOT artifacts so the kernels lower into the shipped HLO.
  * ``use_kernels=False`` — pure-jnp refs, used for fast jitted training.

Parameter naming matches the MCWT tensor names consumed by
``rust/src/moe/weights.rs`` (see DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ref
from .kernels.attention import attention as attention_k
from .kernels.moe_ffn import moe_ffn as moe_ffn_k
from .kernels.token_importance import token_importance as token_importance_k


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_names(cfg: ModelConfig) -> list[str]:
    """Canonical (sorted) tensor-name order used for flat artifact I/O."""
    names = ["tok_emb", "pos_emb", "final_norm", "lm_head"]
    for i in range(cfg.n_layers):
        names += [f"layers.{i}.attn_norm", f"layers.{i}.ffn_norm",
                  f"layers.{i}.gate"]
        names += [f"layers.{i}.attn.{m}" for m in ("wq", "wk", "wv", "wo")]
        for e in range(cfg.n_experts):
            names += [f"layers.{i}.experts.{e}.{m}" for m in ("w1", "w3", "w2")]
    return sorted(names)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    d, f, e, v, s = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.vocab_size, cfg.max_seq

    def dense(key, shape):
        fan_in = shape[0]
        return jax.random.normal(key, shape, jnp.float32) * (fan_in ** -0.5)

    keys = iter(jax.random.split(key, 16 + cfg.n_layers * (8 + 3 * e)))
    p: dict[str, jax.Array] = {
        "tok_emb": jax.random.normal(next(keys), (v, d)) * 0.02,
        "pos_emb": jax.random.normal(next(keys), (s, d)) * 0.02,
        "final_norm": jnp.ones((d,)),
        "lm_head": dense(next(keys), (d, v)),
    }
    for i in range(cfg.n_layers):
        p[f"layers.{i}.attn_norm"] = jnp.ones((d,))
        p[f"layers.{i}.ffn_norm"] = jnp.ones((d,))
        p[f"layers.{i}.gate"] = dense(next(keys), (d, e))
        for m in ("wq", "wk", "wv", "wo"):
            p[f"layers.{i}.attn.{m}"] = dense(next(keys), (d, d))
        for ex in range(cfg.n_experts):
            for m, shape in (("w1", (d, f)), ("w3", (d, f)), ("w2", (f, d))):
                p[f"layers.{i}.experts.{ex}.{m}"] = dense(next(keys), shape)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def gate_probs(x, wg):
    """Router: softmax(x @ Wg) -> [S, E] (paper Eq. 1's G(t))."""
    return jax.nn.softmax(x @ wg, axis=-1)


def manual_top_k(probs, k):
    """argmax-based top-k, identical to jax.lax.top_k (ties -> lower
    index) but lowering to reduce/scatter ops that the pinned
    xla_extension 0.5.1 HLO-text parser accepts — jax >= 0.7 lowers
    lax.top_k to a `topk(..., largest=true)` custom instruction the old
    parser rejects (see DESIGN.md §3 interchange notes)."""
    s = probs.shape[0]
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        idx = jnp.argmax(p, axis=-1)
        val = jnp.take_along_axis(p, idx[:, None], axis=-1)[:, 0]
        vals.append(val)
        idxs.append(idx)
        p = p.at[jnp.arange(s), idx].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_layer(x, layer_params, cfg: ModelConfig, use_kernels: bool):
    """Top-k routed MoE FFN (dense-mixing formulation, exact for top-k).

    Returns (y, probs[S, E]) so calibration can record routing stats.
    """
    probs = gate_probs(x, layer_params["gate"])
    topv, topi = manual_top_k(probs, cfg.top_k)               # [S, k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)        # renormalize
    weights = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None], topi].set(topv)       # [S, E]
    ffn = moe_ffn_k if use_kernels else ref.moe_ffn_ref
    y = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        ex = layer_params["experts"][e]
        y = y + weights[:, e:e + 1] * ffn(x, ex["w1"], ex["w3"], ex["w2"])
    # switch-transformer balance term: E * <frac_selected, mean_prob>
    sel_frac = jnp.mean((weights > 0).astype(jnp.float32), axis=0)   # [E]
    balance = cfg.n_experts * jnp.dot(sel_frac, jnp.mean(probs, axis=0))
    return y, probs, balance


def _layer_view(p: dict[str, jax.Array], i: int, cfg: ModelConfig):
    lp = {
        "attn_norm": p[f"layers.{i}.attn_norm"],
        "ffn_norm": p[f"layers.{i}.ffn_norm"],
        "gate": p[f"layers.{i}.gate"],
        "attn": {m: p[f"layers.{i}.attn.{m}"] for m in ("wq", "wk", "wv", "wo")},
        "experts": [
            {m: p[f"layers.{i}.experts.{e}.{m}"] for m in ("w1", "w3", "w2")}
            for e in range(cfg.n_experts)
        ],
    }
    return lp


def forward_seq(params, cfg: ModelConfig, tokens, mask=None,
                use_kernels: bool = False, collect_aux: bool = False):
    """Single-sequence forward: tokens[S] int32 -> logits[S, V].

    With collect_aux, also returns per-layer routing probs, attention
    maps, and Eq.-6 token importances (the ODP inputs).
    """
    s = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    attn = attention_k if use_kernels else ref.attention_ref
    timp = token_importance_k if use_kernels else ref.token_importance_ref
    aux = {"probs": [], "attn": [], "importance": []} if collect_aux else None
    balance = 0.0
    for i in range(cfg.n_layers):
        lp = _layer_view(params, i, cfg)
        h = ref.rmsnorm_ref(x, lp["attn_norm"])
        a_out, a_map = attn(h, lp["attn"]["wq"], lp["attn"]["wk"],
                            lp["attn"]["wv"], lp["attn"]["wo"],
                            cfg.n_heads, mask)
        x = x + a_out
        h = ref.rmsnorm_ref(x, lp["ffn_norm"])
        if collect_aux:
            aux["attn"].append(a_map)
            aux["importance"].append(timp(h, a_map))
        y, probs, bal = moe_layer(h, lp, cfg, use_kernels)
        balance = balance + bal / cfg.n_layers
        if collect_aux:
            aux["probs"].append(probs)
        x = x + y
    x = ref.rmsnorm_ref(x, params["final_norm"])
    logits = x @ params["lm_head"]
    if collect_aux:
        return logits, aux
    return logits, balance


def forward(params, cfg: ModelConfig, tokens, use_kernels: bool = False):
    """Batched forward: tokens[B, S] -> logits[B, S, V]."""
    logits, _ = jax.vmap(
        lambda t: forward_seq(params, cfg, t, use_kernels=use_kernels)
    )(tokens)
    return logits


def train_forward(params, cfg: ModelConfig, tokens):
    """Batched training forward: tokens[B, S] -> (logits[B, S, V], balance).

    Mathematically identical to vmap(forward_seq) (asserted by
    test_model.test_train_forward_matches_seq) but structured for CPU
    XLA: attention is one [B,H,S,S] einsum and the MoE runs on the
    flattened [B*S, D] token matrix, so every matmul is large.
    """
    b, s = tokens.shape
    d, e, nh = cfg.d_model, cfg.n_experts, cfg.n_heads
    hd = d // nh
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s]
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    balance = 0.0
    for i in range(cfg.n_layers):
        lp = _layer_view(params, i, cfg)
        h = ref.rmsnorm_ref(x, lp["attn_norm"])
        q = (h @ lp["attn"]["wq"]).reshape(b, s, nh, hd)
        k = (h @ lp["attn"]["wk"]).reshape(b, s, nh, hd)
        v = (h @ lp["attn"]["wv"]).reshape(b, s, nh, hd)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        scores = jnp.where(causal[None, None], scores, -1e30)
        a = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, d)
        x = x + o @ lp["attn"]["wo"]
        h = ref.rmsnorm_ref(x, lp["ffn_norm"]).reshape(b * s, d)
        probs = gate_probs(h, lp["gate"])                       # [BS, E]
        topv, topi = manual_top_k(probs, cfg.top_k)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
        weights = jnp.zeros_like(probs).at[
            jnp.arange(b * s)[:, None], topi].set(topv)
        y = jnp.zeros_like(h)
        for ex in range(e):
            exp = lp["experts"][ex]
            y = y + weights[:, ex:ex + 1] * ref.moe_ffn_ref(
                h, exp["w1"], exp["w3"], exp["w2"])
        sel_frac = jnp.mean((weights > 0).astype(jnp.float32), axis=0)
        balance = balance + e * jnp.dot(
            sel_frac, jnp.mean(probs, axis=0)) / cfg.n_layers
        x = x + y.reshape(b, s, d)
    x = ref.rmsnorm_ref(x, params["final_norm"])
    return x @ params["lm_head"], balance


def loss_fn(params, cfg: ModelConfig, x, y, aux_coef: float = 1e-2):
    """Next-token cross-entropy + switch balance auxiliary loss."""
    logits, balance = train_forward(params, cfg, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    keep = (y != 0).astype(jnp.float32)
    ce = jnp.sum(nll * keep) / jnp.maximum(jnp.sum(keep), 1.0)
    return ce + aux_coef * jnp.mean(balance)
