"""Packing spec tests: the canonical layout both languages must honor."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.config import GROUP_SIZE, VALS_PER_WORD
from compile.kernels import packing


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("k,n", [(64, 8), (128, 16), (256, 3), (130, 5)])
def test_pack_unpack_roundtrip(bits, k, n):
    rng = np.random.default_rng(0)
    q = rng.integers(0, 2**bits, size=(k, n)).astype(np.int32)
    packed = packing.pack_bits(q, bits)
    assert packed.dtype == np.uint32
    vpw = VALS_PER_WORD[bits]
    assert packed.shape == ((k + vpw - 1) // vpw, n)
    out = packing.unpack_bits(packed, bits, k)
    np.testing.assert_array_equal(out, q)


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_three_bit_top_bits_zero(bits):
    """3-bit packs 10 fields into 30 bits; stray high bits must be zero."""
    rng = np.random.default_rng(1)
    q = rng.integers(0, 2**bits, size=(40, 4)).astype(np.int32)
    packed = packing.pack_bits(q, bits)
    if bits == 3:
        assert np.all(packed >> np.uint32(30) == 0)


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_groupwise_quant_bounds(bits):
    """Group-wise min/max quantization error <= scale/2 per element."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(128, 32)).astype(np.float32)
    q, s, z = packing.quantize_groupwise(w, bits)
    wq = packing.dequantize_groupwise(q, s, z)
    g = 128 // GROUP_SIZE
    err = np.abs(w - wq).reshape(g, GROUP_SIZE, 32).max(axis=1)
    assert np.all(err <= s * 0.5 + 1e-6)


def test_quant_extremes_hit_range():
    rng = np.random.default_rng(3)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    q, _, _ = packing.quantize_groupwise(w, 2)
    assert q.min() == 0 and q.max() == 3


@given(st.integers(1, 4), st.integers(1, 6), st.sampled_from([2, 3, 4]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_roundtrip_hypothesis(kw, n, bits, seed):
    k = kw * VALS_PER_WORD[bits]
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 2**bits, size=(k, n)).astype(np.int32)
    np.testing.assert_array_equal(
        packing.unpack_bits(packing.pack_bits(q, bits), bits, k), q)


def test_binarize_roundtrip_signs():
    rng = np.random.default_rng(4)
    w = rng.normal(size=(96, 16)).astype(np.float32)
    packed, s = packing.binarize(w)
    wr = packing.debinarize(packed, s, 96)
    # reconstructed signs match original signs (w==0 -> +1)
    np.testing.assert_array_equal(np.sign(wr), np.where(w >= 0, 1.0, -1.0))
    # per-column scale is the column mean |w|
    np.testing.assert_allclose(s, np.abs(w).mean(axis=0), rtol=1e-6)


def test_binarize_scalar_scale_matches_paper():
    """Paper Eq. 10: s = ||W||_1 / (d*m), one scalar per matrix."""
    rng = np.random.default_rng(5)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    _, s = packing.binarize(w, scalar_scale=True)
    expected = np.abs(w).sum() / (64 * 8)
    np.testing.assert_allclose(s, np.full(8, expected), rtol=1e-6)


def test_binarize_non_multiple_of_32_rows():
    rng = np.random.default_rng(6)
    w = rng.normal(size=(50, 4)).astype(np.float32)
    packed, s = packing.binarize(w)
    assert packed.shape == (2, 4)
    wr = packing.debinarize(packed, s, 50)
    assert wr.shape == (50, 4)
    np.testing.assert_array_equal(np.sign(wr), np.where(w >= 0, 1.0, -1.0))
