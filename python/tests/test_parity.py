"""Cross-language contracts: values the rust side pins must match the
python generators (the other direction is pinned in rust unit tests)."""

import numpy as np

from compile.datagen import TextChannel
from compile.kernels import packing


def test_text_channel_fingerprints():
    """rust/src/data/text.rs pins these exact values."""
    t = TextChannel()
    assert list(t.succ[0][:12]) == [75, 67, 94, 40, 74, 101, 63, 7, 77, 78, 55, 53]
    assert [int(t.succ[i].sum()) for i in range(4)] == [784, 580, 678, 947]


def test_lcg_first_output():
    """rust/src/util/rng.rs pins lcg_next(0xC0FFEE)."""
    v = (0xC0FFEE * 6364136223846793005 + 1442695040888963407) % 2**64
    assert v == 0xF4690D0475D19025


def test_packing_golden_vector():
    """rust/src/quant/pack.rs pins this 2-bit packing."""
    q = np.array([[1, 2], [3, 0], [2, 1], [0, 3]], dtype=np.int32)
    packed = packing.pack_bits(q, 2)
    assert packed.tolist() == [[0x2D, 0xD2]]


def test_task_token_ranges_match_rust_constants():
    from compile import config as c
    # rust/src/config.rs constants
    assert (c.PAD, c.BOS, c.EOS, c.SEP, c.QRY) == (0, 1, 2, 3, 4)
    assert (c.TASK_BASE, c.NUM_BASE, c.SYM_BASE, c.TXT_BASE) == (5, 16, 80, 144)
    assert (c.NUM_COUNT, c.SYM_COUNT, c.TXT_COUNT) == (64, 64, 112)
    assert c.GROUP_SIZE == 64
    assert c.VALS_PER_WORD == {2: 16, 3: 10, 4: 8}
