"""L1 resource-model guards: every kernel fits VMEM at every config."""

import pytest

from compile.config import CONFIGS
from compile.kernels.roofline import (
    VMEM_BYTES, all_estimates, hbm_compression_ratio, mxu_utilization,
)


@pytest.mark.parametrize("name", list(CONFIGS))
def test_kernels_fit_vmem(name):
    cfg = CONFIGS[name]()
    for e in all_estimates(cfg):
        assert e.vmem_bytes < VMEM_BYTES, f"{name}/{e.name}: {e.vmem_bytes}"


def test_mxu_utilization_bounds():
    assert mxu_utilization(128, 128, 128) == 1.0
    assert 0.0 < mxu_utilization(1, 128, 128) < 0.2
    # padding both dims compounds
    assert mxu_utilization(8, 100, 100) < mxu_utilization(8, 128, 128)


@pytest.mark.parametrize("bits,expect_max", [(2, 0.20), (3, 0.25)])
def test_hbm_compression(bits, expect_max):
    """Packed expert weights must cut HBM traffic to <= ~bits/16 + params."""
    cfg = CONFIGS["tiny"]()
    ratio = hbm_compression_ratio(cfg, bits)
    assert ratio < expect_max, ratio
    assert ratio > bits / 32  # can't beat information content


def test_quant_kernels_higher_arithmetic_intensity():
    """The fused dequant kernel reads less HBM per FLOP than dense f32
    (the entire point of the HQQ-analogue kernel)."""
    from compile.kernels.roofline import moe_ffn_estimate, quant_matmul_estimate
    cfg = CONFIGS["tiny"]()
    q = quant_matmul_estimate(cfg, 2)
    ai_q = q.flops / q.hbm_bytes
    dense = moe_ffn_estimate(cfg)
    ai_d = dense.flops / dense.hbm_bytes / 3  # 3 matmuls in the ffn
    assert ai_q > ai_d
