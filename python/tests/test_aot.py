"""AOT export path: HLO text generation + manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import packed_shapes, to_hlo_text
from compile.config import GROUP_SIZE, VALS_PER_WORD, ModelConfig
from compile.model import forward_seq, init_params, param_names


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="aot-test", d_model=32, n_layers=1, n_heads=2,
                      d_ff=64, n_experts=4, max_seq=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_hlo_text_exports_and_is_parseable_header(tiny):
    cfg, params = tiny
    names = param_names(cfg)

    def fn(tokens, *flat):
        p = dict(zip(names, flat))
        logits, _ = forward_seq(p, cfg, tokens, use_kernels=True)
        return (logits,)

    specs = [jax.ShapeDtypeStruct((cfg.max_seq,), jnp.int32)] + [
        jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names
    ]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # no topk custom instruction (xla_extension 0.5.1 can't parse it)
    assert " topk(" not in text, "manual_top_k regression: topk op leaked"


def test_packed_shapes_consistency():
    for bits in (2, 3, 4):
        (kw, n), (g, n2), (g2, n3) = packed_shapes(128, 64, bits)
        assert n == n2 == n3 == 64
        assert kw == -(-128 // VALS_PER_WORD[bits])
        assert g == g2 == 128 // GROUP_SIZE
    pshape, sshape, z = packed_shapes(128, 64, 1)
    assert pshape == (4, 64)
    assert sshape == (64,)
    assert z is None


def test_artifacts_manifest_if_built():
    """When artifacts exist, manifest shapes must match packing math."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    cfgd = json.load(open(os.path.join(art, "config.json")))
    d, f, t = cfgd["d_model"], cfgd["d_ff"], cfgd["prefill_tile"]
    q2 = manifest["artifacts"]["expert_ffn_q2"]["inputs"]
    by_name = {io["name"]: io for io in q2}
    assert by_name["x"]["shape"] == [t, d]
    (kw, _), (g, _), _ = packed_shapes(d, f, 2)
    assert by_name["qw1"]["shape"] == [kw, f]
    assert by_name["s1"]["shape"] == [g, f]
    assert by_name["qw1"]["dtype"] == "u32"
    # model_fwd carries tokens + every parameter
    mf = manifest["artifacts"]["model_fwd"]
    assert len(mf["inputs"]) == 1 + len(manifest["param_order"])


def test_golden_file_consistent_if_built():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    gpath = os.path.join(art, "golden.mcwt")
    if not os.path.exists(gpath):
        pytest.skip("artifacts not built")
    from compile import mcwt
    from compile.config import ModelConfig as MC
    golden = mcwt.read(gpath)
    cfg = MC.from_json(open(os.path.join(art, "config.json")).read())
    assert golden["tokens"].shape == (cfg.max_seq,)
    assert golden["logits"].shape == (cfg.max_seq, cfg.vocab_size)
    assert golden["probs_l0"].shape == (cfg.max_seq, cfg.n_experts)
    np.testing.assert_allclose(golden["probs_l0"].sum(-1), 1.0, rtol=1e-4)
