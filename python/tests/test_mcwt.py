"""MCWT format roundtrip + layout guarantees."""

import numpy as np
import pytest

from compile import mcwt


def test_roundtrip(tmp_path):
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.c.d": np.array([1.5, -2.5], dtype=np.float32),
        "scalar3d": np.zeros((2, 2, 2), dtype=np.float32),
    }
    path = str(tmp_path / "w.mcwt")
    mcwt.write(path, tensors)
    out = mcwt.read(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].shape == tensors[k].shape


def test_alignment(tmp_path):
    """Every tensor payload starts at a 64-byte-aligned offset."""
    import json
    tensors = {f"t{i}": np.ones(7, dtype=np.float32) for i in range(5)}
    path = str(tmp_path / "w.mcwt")
    mcwt.write(path, tensors)
    raw = open(path, "rb").read()
    hlen = int(np.frombuffer(raw[8:12], np.uint32)[0])
    header = json.loads(raw[12:12 + hlen])
    for meta in header["tensors"].values():
        assert meta["offset"] % 64 == 0


def test_magic_and_version(tmp_path):
    path = str(tmp_path / "w.mcwt")
    mcwt.write(path, {"x": np.zeros(1, np.float32)})
    raw = open(path, "rb").read()
    assert raw[:4] == b"MCWT"
    assert int(np.frombuffer(raw[4:8], np.uint32)[0]) == 1


def test_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "bad.mcwt")
    open(path, "wb").write(b"XXXX" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        mcwt.read(path)


def test_non_contiguous_input(tmp_path):
    arr = np.arange(24, dtype=np.float32).reshape(4, 6)[:, ::2]
    path = str(tmp_path / "w.mcwt")
    mcwt.write(path, {"x": arr})
    np.testing.assert_array_equal(mcwt.read(path)["x"], arr)
