"""L1 Pallas kernels vs pure-jnp oracle (the CORE correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.config import GROUP_SIZE
from compile.kernels import packing, ref
from compile.kernels.attention import attention
from compile.kernels.binary_matmul import binary_matmul
from compile.kernels.moe_ffn import moe_ffn
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.token_importance import token_importance


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,h", [(16, 32, 4), (64, 64, 8), (32, 48, 3)])
def test_attention_matches_ref(s, d, h):
    rng = np.random.default_rng(0)
    x = rand(rng, s, d)
    ws = [rand(rng, d, d) for _ in range(4)]
    y_k, a_k = attention(x, *ws, n_heads=h)
    y_r, a_r = ref.attention_ref(x, *ws, n_heads=h)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(a_k, a_r, rtol=2e-4, atol=2e-6)


def test_attention_rows_sum_to_one():
    rng = np.random.default_rng(1)
    x = rand(rng, 24, 32)
    ws = [rand(rng, 32, 32) for _ in range(4)]
    _, a = attention(x, *ws, n_heads=4)
    np.testing.assert_allclose(np.asarray(a).sum(-1), 1.0, rtol=1e-5)


def test_attention_causal():
    """Future keys must receive zero attention."""
    rng = np.random.default_rng(2)
    x = rand(rng, 16, 32)
    ws = [rand(rng, 32, 32) for _ in range(4)]
    _, a = attention(x, *ws, n_heads=4)
    a = np.asarray(a)
    upper = np.triu(np.ones((16, 16), dtype=bool), k=1)
    assert np.all(a[:, upper] == 0)


def test_attention_key_mask():
    """Masked-out keys get zero attention from all queries."""
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 32)
    ws = [rand(rng, 32, 32) for _ in range(4)]
    mask = jnp.asarray([1] * 12 + [0] * 4, dtype=jnp.int32)
    _, a = attention(x, *ws, n_heads=4, mask=mask)
    assert np.all(np.asarray(a)[:, :, 12:][:, :12, :] == 0)


# ---------------------------------------------------------------------------
# moe_ffn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d,f", [(8, 32, 64), (128, 64, 128), (256, 48, 96)])
def test_moe_ffn_matches_ref(m, d, f):
    rng = np.random.default_rng(4)
    x, w1, w3, w2 = rand(rng, m, d), rand(rng, d, f), rand(rng, d, f), rand(rng, f, d)
    np.testing.assert_allclose(
        moe_ffn(x, w1, w3, w2, block_m=min(64, m)),
        ref.moe_ffn_ref(x, w1, w3, w2), rtol=3e-4, atol=3e-5)


def test_moe_ffn_multi_tile_equals_single_tile():
    rng = np.random.default_rng(5)
    x, w1, w3, w2 = rand(rng, 128, 32), rand(rng, 32, 64), rand(rng, 32, 64), rand(rng, 64, 32)
    np.testing.assert_allclose(
        moe_ffn(x, w1, w3, w2, block_m=32),
        moe_ffn(x, w1, w3, w2, block_m=128), rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("m,k,n", [(4, 64, 32), (16, 128, 128), (8, 192, 64)])
def test_quant_matmul_matches_ref(bits, m, k, n):
    rng = np.random.default_rng(6)
    x = rand(rng, m, k)
    w = rng.normal(size=(k, n)).astype(np.float32)
    q, s, z = packing.quantize_groupwise(w, bits)
    qw = jnp.asarray(packing.pack_bits(q, bits))
    s, z = jnp.asarray(s), jnp.asarray(z)
    y_k = quant_matmul(x, qw, s, z, bits, block_n=min(32, n))
    y_r = ref.quant_matmul_ref(x, qw, s, z, bits)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)


def test_quant_matmul_vs_dense_dequant():
    """Kernel output == x @ (numpy-dequantized W): the end-to-end contract."""
    rng = np.random.default_rng(7)
    k, n, bits = 128, 64, 3
    x = rand(rng, 8, k)
    w = rng.normal(size=(k, n)).astype(np.float32)
    q, s, z = packing.quantize_groupwise(w, bits)
    wq = packing.dequantize_groupwise(q, s, z)
    y = quant_matmul(x, jnp.asarray(packing.pack_bits(q, bits)),
                     jnp.asarray(s), jnp.asarray(z), bits, block_n=64)
    np.testing.assert_allclose(y, np.asarray(x) @ wq, rtol=2e-4, atol=2e-4)


@given(st.sampled_from([2, 3, 4]), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_quant_matmul_hypothesis(bits, kg, nt, seed):
    k, n = kg * GROUP_SIZE, nt * 16
    rng = np.random.default_rng(seed)
    x = rand(rng, 3, k)
    w = rng.normal(size=(k, n)).astype(np.float32)
    q, s, z = packing.quantize_groupwise(w, bits)
    qw = jnp.asarray(packing.pack_bits(q, bits))
    y_k = quant_matmul(x, qw, jnp.asarray(s), jnp.asarray(z), bits, block_n=16)
    y_r = ref.quant_matmul_ref(x, qw, jnp.asarray(s), jnp.asarray(z), bits)
    np.testing.assert_allclose(y_k, y_r, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# binary_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(4, 64, 32), (16, 128, 64), (8, 96, 16)])
def test_binary_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(8)
    x = rand(rng, m, k)
    w = rng.normal(size=(k, n)).astype(np.float32)
    packed, s = packing.binarize(w)
    y_k = binary_matmul(x, jnp.asarray(packed), jnp.asarray(s), block_n=16)
    y_r = ref.binary_matmul_ref(x, jnp.asarray(packed), jnp.asarray(s), k)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)


def test_binary_matmul_eq10_identity():
    """x @ ((2b-1)*s) == s*(sum_{b=1} x - sum_{b=0} x) — paper Eq. 10."""
    rng = np.random.default_rng(9)
    k, n = 64, 8
    x = rng.normal(size=(2, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    packed, s = packing.binarize(w)
    btilde = (packing.debinarize(packed, np.ones(n, np.float32), k) + 1) / 2
    manual = np.zeros((2, n), np.float32)
    for i in range(n):
        on = btilde[:, i] == 1
        manual[:, i] = s[i] * (x[:, on].sum(-1) - x[:, ~on].sum(-1))
    y = binary_matmul(jnp.asarray(x), jnp.asarray(packed), jnp.asarray(s),
                      block_n=8)
    np.testing.assert_allclose(y, manual, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# token_importance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,h", [(16, 32, 2), (64, 48, 4)])
def test_token_importance_matches_ref(s, d, h):
    rng = np.random.default_rng(10)
    x = rand(rng, s, d)
    logits = rng.normal(size=(h, s, s)).astype(np.float32)
    a = jnp.asarray(np.exp(logits) / np.exp(logits).sum(-1, keepdims=True))
    np.testing.assert_allclose(token_importance(x, a),
                               ref.token_importance_ref(x, a),
                               rtol=2e-4, atol=2e-5)


def test_token_importance_scales_with_magnitude():
    """Doubling a token's hidden state doubles its importance (Eq. 6)."""
    rng = np.random.default_rng(11)
    x = np.abs(rng.normal(size=(8, 16))).astype(np.float32)
    a = np.full((1, 8, 8), 1.0 / 8, np.float32)
    base = np.asarray(token_importance(jnp.asarray(x), jnp.asarray(a)))
    x2 = x.copy()
    x2[3] *= 2
    double = np.asarray(token_importance(jnp.asarray(x2), jnp.asarray(a)))
    np.testing.assert_allclose(double[3], 2 * base[3], rtol=1e-5)
