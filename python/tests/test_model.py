"""L2 model tests: kernel path == ref path, training sanity, shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen
from compile.config import ModelConfig
from compile.model import forward_seq, init_params, loss_fn, param_names


@pytest.fixture(scope="module")
def tiny_cfg():
    return ModelConfig(name="test", d_model=32, n_layers=2, n_heads=2,
                       d_ff=64, n_experts=4, max_seq=32, train_seq=16)


@pytest.fixture(scope="module")
def params(tiny_cfg):
    return init_params(tiny_cfg, jax.random.PRNGKey(0))


def test_param_names_cover_params(tiny_cfg, params):
    assert sorted(params.keys()) == param_names(tiny_cfg)


def test_param_count_matches(tiny_cfg, params):
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == tiny_cfg.param_count()


def test_kernel_and_ref_paths_match(tiny_cfg, params):
    toks = jnp.asarray(np.arange(32) % 250 + 1, dtype=jnp.int32)
    lk, _ = forward_seq(params, tiny_cfg, toks, use_kernels=True)
    lr, _ = forward_seq(params, tiny_cfg, toks, use_kernels=False)
    np.testing.assert_allclose(lk, lr, rtol=5e-4, atol=5e-5)


def test_forward_is_causal(tiny_cfg, params):
    """Changing a future token must not change past logits."""
    toks = np.arange(32, dtype=np.int32) % 200 + 1
    l1, _ = forward_seq(params, tiny_cfg, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[20] = 99
    l2, _ = forward_seq(params, tiny_cfg, jnp.asarray(toks2))
    np.testing.assert_allclose(l1[:20], l2[:20], rtol=1e-5, atol=1e-6)
    assert not np.allclose(l1[20:], l2[20:])


def test_collect_aux_shapes(tiny_cfg, params):
    toks = jnp.asarray(np.arange(32) % 200 + 1, dtype=jnp.int32)
    logits, aux = forward_seq(params, tiny_cfg, toks, collect_aux=True)
    assert logits.shape == (32, tiny_cfg.vocab_size)
    assert len(aux["probs"]) == tiny_cfg.n_layers
    assert aux["probs"][0].shape == (32, tiny_cfg.n_experts)
    assert aux["attn"][0].shape == (tiny_cfg.n_heads, 32, 32)
    assert aux["importance"][0].shape == (32,)
    # router probs are a distribution
    np.testing.assert_allclose(np.asarray(aux["probs"][0]).sum(-1), 1.0,
                               rtol=1e-5)


def test_loss_decreases_with_training(tiny_cfg):
    """A handful of adam steps on one batch must reduce the loss."""
    from compile.train import make_train_step
    params = init_params(tiny_cfg, jax.random.PRNGKey(1))
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    grad_fn, adam = make_train_step(tiny_cfg)
    rng = np.random.default_rng(0)
    text = datagen.TextChannel()
    x, y = next(datagen.batches(rng, text, 1, 8, 16))
    x, y = jnp.asarray(x), jnp.asarray(y)
    l0, g = grad_fn(params, x, y)
    for step in range(1, 21):
        loss, g = grad_fn(params, x, y)
        params, m, v = adam(params, g, m, v, step, 1e-2)
    l1, _ = grad_fn(params, x, y)
    assert float(l1) < float(l0) * 0.8, (float(l0), float(l1))


def test_datagen_task_sequences_well_formed():
    rng = np.random.default_rng(2)
    for task in range(8):
        for _ in range(20):
            seq = datagen.task_sequence(rng, task)
            assert seq[0] == 1 and seq[-1] == 2  # BOS..EOS
            assert 3 in seq[2:-1]                # SEP present
            assert all(0 <= t < 256 for t in seq)


def test_text_channel_deterministic_table():
    t1 = datagen.TextChannel()
    t2 = datagen.TextChannel()
    np.testing.assert_array_equal(t1.succ, t2.succ)
    assert t1.succ.shape == (112, 12)
    assert np.all(t1.succ < 112)


def test_train_forward_matches_seq(tiny_cfg, params):
    """Batched training forward == per-sequence forward (same math)."""
    from compile.model import train_forward
    toks = np.stack([np.arange(32) % 200 + 1,
                     (np.arange(32) * 7) % 199 + 1]).astype(np.int32)
    lt, _ = train_forward(params, tiny_cfg, jnp.asarray(toks))
    for i in range(2):
        ls, _ = forward_seq(params, tiny_cfg, jnp.asarray(toks[i]))
        np.testing.assert_allclose(lt[i], ls, rtol=2e-3, atol=2e-4)
